"""`TwinDriver`: the in-process digital-twin implementation of the ABC.

Wraps a :class:`DeviceRealization` + :class:`DriftState` behind the
:class:`~repro.hw.driver.PhotonicDriver` surface.  All ops evaluate the
same pure twin physics (``repro.hw.device``) the simulator has always
used, so the driver boundary costs nothing numerically; the in-situ
jobs delegate to ``repro.hw.jobs`` (vmapped ``lax.scan`` searches — the
jit-friendly path).

Drift entropy is device-owned: the driver holds its own PRNG chain
(seeded at construction), so a fleet trajectory is reproducible from
construction seeds alone and the control plane never supplies drift
randomness — mirroring real hardware, which drifts without being asked.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core import unitary as un
from ..core.noise import NoiseModel
from ..optim.zo import ZOConfig
from . import jobs
from .device import (DeviceRealization, sample_device, realized_unitaries,
                     realized_blocks, true_mapping_distance, chip_forward)
from .drift import DriftConfig, DriftState, init_drift, advance, \
    bias_deviation
from .driver import (PhotonicDriver, DriverStats, ZORefineResult, ICJobResult,
                     probe_cost, readback_cost, resolve_block_range,
                     forward_coalesce_key, coalesce_spans,
                     validate_batch_ops)

__all__ = ["TwinDriver", "TwinHandle", "make_twin"]


class TwinHandle:
    """Quarantined readouts of a twin's internals (tests/benchmarks only).

    Obtained exclusively through ``driver.unsafe_twin()`` — the single
    audited hole in the observability boundary.
    """

    def __init__(self, driver: "TwinDriver"):
        self._d = driver

    @property
    def dev(self) -> DeviceRealization:
        """The current (drifted) device realization."""
        return self._d._state.dev

    @property
    def anchor(self) -> DeviceRealization:
        """The manufacturing realization the OU drift reverts to."""
        return self._d._state.anchor

    @property
    def drift_state(self) -> DriftState:
        return self._d._state

    def realized_unitaries(self) -> tuple[jax.Array, jax.Array]:
        """Free full readout of the realized bases (no PTC charge)."""
        d = self._d
        t = d._spec.n_rot
        return realized_unitaries(d._spec, d._phi[:, :t], d._phi[:, t:],
                                  d._state.dev, d._model)

    def realized_blocks(self) -> jax.Array:
        d = self._d
        return realized_blocks(d._spec, d._phi, d._sigma, d._state.dev,
                               d._model)

    def true_mapping_distance(self, w_blocks: jax.Array,
                              block_range: tuple[int, int] | None = None
                              ) -> float:
        """Exact aggregate mapping distance (full-readout ground truth).
        ``block_range`` scopes it to one tenant's blocks (``w_blocks``
        then carries the range's block count)."""
        d = self._d
        start, stop = resolve_block_range(d._b, block_range)
        dev = jax.tree_util.tree_map(lambda a: a[start:stop], d._state.dev)
        return float(true_mapping_distance(
            d._spec, d._phi[start:stop], d._sigma[start:stop], dev,
            d._model, w_blocks))

    def bias_deviation(self) -> float:
        """RMS phase-bias deviation from the anchor (radians)."""
        return float(bias_deviation(self._d._state))


def _scope(phi, sigma, dev, start: int, stop: int):
    """Tenant-scope the commanded state + device INSIDE the compiled
    graph: ``start``/``stop`` are static, so each (shape, block_range)
    signature compiles once and the per-call python cost is a pure
    cache-hit dispatch — the twin fast path the stream servers also ride."""
    dev = jax.tree_util.tree_map(lambda a: a[start:stop], dev)
    return phi[start:stop], sigma[start:stop], dev


@functools.lru_cache(maxsize=64)
def _jitted_probe_ops(k: int, kind: str, model: NoiseModel,
                      use_kernels: bool):
    """Compiled forward/readback graphs keyed on the driver's static
    physics (NoiseModel is a frozen dataclass, hence hashable).

    With ``use_kernels`` (default on TPU backends) the probe forward is
    routed through the Pallas PTC kernel (``kernels.ptc_block_matmul``,
    the production serve-path dataflow: per-block V* → Σ → U on the
    MXU); elsewhere the XLA einsum of the same physics is faster than
    interpret-mode Pallas and is used instead.
    """
    spec = un.mesh_spec(k, kind)
    t = spec.n_rot

    @functools.partial(jax.jit, static_argnums=(4, 5))
    def fwd(phi, sigma, dev, x, start, stop):
        phi, sigma, dev = _scope(phi, sigma, dev, start, stop)
        if use_kernels:
            from ..kernels import ops as kops
            u, v = realized_unitaries(spec, phi[:, :t], phi[:, t:], dev,
                                      model)
            # per-block probe = the PTC kernel on a (B, 1) block grid
            y = kops.ptc_block_matmul(x, u[:, None], sigma[:, None],
                                      v[:, None])          # (n, B·k)
            return jnp.transpose(
                y.reshape(x.shape[0], stop - start, k), (1, 0, 2))
        return jnp.einsum(
            "bij,nj->bni", realized_blocks(spec, phi, sigma, dev, model), x)

    @functools.partial(jax.jit, static_argnums=(2, 3))
    def readback(phi, dev, start, stop):
        dev = jax.tree_util.tree_map(lambda a: a[start:stop], dev)
        phi = phi[start:stop]
        return realized_unitaries(spec, phi[:, :t], phi[:, t:], dev, model)

    @functools.partial(jax.jit, static_argnums=(4, 5))
    def fwd_many(phi, sigma, dev, xs, start, stop):
        # N same-shape probe ops in one compiled call, vmapped over the
        # op axis — bit-identical to N separate fwd calls (each output
        # element's contraction is unchanged; the conformance suite
        # asserts it) at ~1/30 the per-op dispatch cost
        return jax.vmap(
            lambda x: fwd(phi, sigma, dev, x, start, stop))(xs)

    return fwd, readback, fwd_many


@functools.lru_cache(maxsize=256)
def _jitted_layer(k: int, kind: str, model: NoiseModel, m_out: int,
                  use_kernels: bool):
    """Compiled serve-path graph, keyed additionally on the output dim —
    each tenant geometry compiles once and is shared fleet-wide.  On TPU
    the assembled P×Q grid forward runs through the Pallas PTC kernel."""
    spec = un.mesh_spec(k, kind)
    t = spec.n_rot

    @functools.partial(jax.jit, static_argnums=(4, 5))
    def layer(phi, sigma, dev, x, start, stop):
        phi, sigma, dev = _scope(phi, sigma, dev, start, stop)
        if use_kernels:
            from ..kernels import ops as kops
            b = stop - start
            p = -(-m_out // k)
            q = b // p
            u, v = realized_unitaries(spec, phi[:, :t], phi[:, t:], dev,
                                      model)
            xf = x.reshape((-1, x.shape[-1]))
            n = q * k
            if xf.shape[-1] != n:
                xf = jnp.pad(xf, [(0, 0), (0, n - xf.shape[-1])])
            y = kops.ptc_block_matmul(
                xf, u.reshape(p, q, k, k), sigma.reshape(p, q, k),
                v.reshape(p, q, k, k))                     # (T, p·k)
            return y[:, :m_out].reshape(x.shape[:-1] + (m_out,))
        return chip_forward(spec, phi, sigma, dev, model, x, m_out)

    return layer


class TwinDriver(PhotonicDriver):
    """In-process digital twin behind the control-plane ABC."""

    def __init__(self, dev: DeviceRealization, k: int, model: NoiseModel,
                 kind: str = "clements", m: int | None = None,
                 n: int | None = None, drift: DriftConfig | None = None,
                 drift_key: jax.Array | None = None,
                 use_kernels: bool | None = None):
        self._spec = un.mesh_spec(k, kind)
        self._kind = kind
        self._model = model
        self._state = init_drift(dev)
        self._drift_cfg = drift
        self._drift_key = (drift_key if drift_key is not None
                           else jax.random.PRNGKey(0))
        b = int(dev.d_u.shape[0])
        t = self._spec.n_rot
        self._b = b
        self._phi = jnp.zeros((b, 2 * t), jnp.float32)
        self._sigma = jnp.ones((b, k), jnp.float32)
        # default layer geometry: a 1×B grid (calibration-style chips)
        self._m = int(m) if m is not None else k
        self._n = int(n) if n is not None else k * b
        self._stats = DriverStats()
        # route the forward paths through the Pallas PTC kernel on TPU
        # (the production dataflow); XLA einsum elsewhere — interpret-mode
        # Pallas would undo the fast path on CPU hosts
        self._use_kernels = (bool(use_kernels) if use_kernels is not None
                             else jax.default_backend() == "tpu")
        # jitted probe paths, shared across drivers with the same physics
        # (a fleet of N identical chips compiles each graph once, not N×);
        # block-range scoping is compiled in as a static arg, so each
        # (shape, block_range) signature is a pure cache-hit per call
        self._jit_forward, self._jit_readback, self._jit_forward_many = \
            _jitted_probe_ops(k, kind, model, self._use_kernels)

    def _slice(self, block_range):
        """(start, stop, phi, sigma, dev) scoped to ``block_range``."""
        start, stop = resolve_block_range(self._b, block_range)
        if (start, stop) == (0, self._b):
            return start, stop, self._phi, self._sigma, self._state.dev
        dev = jax.tree_util.tree_map(lambda a: a[start:stop],
                                     self._state.dev)
        return start, stop, self._phi[start:stop], self._sigma[start:stop], \
            dev

    # -- geometry ------------------------------------------------------------

    @property
    def k(self) -> int:
        return self._spec.k

    @property
    def kind(self) -> str:
        return self._kind

    @property
    def n_blocks(self) -> int:
        return self._b

    @property
    def layer_shape(self) -> tuple[int, int]:
        return self._m, self._n

    # -- commanded state -----------------------------------------------------

    def write_phases(self, phi_u: jax.Array, phi_v: jax.Array, *,
                     block_range=None) -> None:
        t = self._spec.n_rot
        start, stop = resolve_block_range(self._b, block_range)
        nb = stop - start
        phi_u = jnp.asarray(phi_u, jnp.float32).reshape(nb, t)
        phi_v = jnp.asarray(phi_v, jnp.float32).reshape(nb, t)
        phi = jnp.concatenate([phi_u, phi_v], axis=-1)
        self._phi = phi if nb == self._b else \
            self._phi.at[start:stop].set(phi)

    def write_sigma(self, sigma: jax.Array, *, block_range=None) -> None:
        start, stop = resolve_block_range(self._b, block_range)
        sigma = jnp.asarray(sigma, jnp.float32).reshape(stop - start, self.k)
        self._sigma = sigma if stop - start == self._b else \
            self._sigma.at[start:stop].set(sigma)

    def write_signs(self, d_u: jax.Array, d_v: jax.Array, *,
                    block_range=None) -> None:
        start, stop = resolve_block_range(self._b, block_range)
        nb = stop - start
        d_u = jnp.asarray(d_u, jnp.float32).reshape(nb, self.k)
        d_v = jnp.asarray(d_v, jnp.float32).reshape(nb, self.k)
        if nb != self._b:
            d_u = self._state.dev.d_u.at[start:stop].set(d_u)
            d_v = self._state.dev.d_v.at[start:stop].set(d_v)
        # signs are topological: they configure both the live device and
        # the drift anchor (OU never walks them)
        self._state = DriftState(
            anchor=self._state.anchor._replace(d_u=d_u, d_v=d_v),
            dev=self._state.dev._replace(d_u=d_u, d_v=d_v),
            t=self._state.t)

    def read_phases(self) -> tuple[jax.Array, jax.Array]:
        t = self._spec.n_rot
        return self._phi[:, :t], self._phi[:, t:]

    def read_sigma(self) -> jax.Array:
        return self._sigma

    # -- probes --------------------------------------------------------------

    def forward(self, x: jax.Array, category: str = "probe", *,
                block_range=None) -> jax.Array:
        x = jnp.asarray(x, jnp.float32)
        start, stop = resolve_block_range(self._b, block_range)
        y = self._jit_forward(self._phi, self._sigma, self._state.dev, x,
                              start, stop)
        self._stats.charge(category, probe_cost(stop - start, x.shape[0]))
        return y

    def forward_layer(self, x: jax.Array, *, block_range=None,
                      out_dim: int | None = None) -> jax.Array:
        x = jnp.asarray(x, jnp.float32)
        start, stop = resolve_block_range(self._b, block_range)
        m_out = int(out_dim) if out_dim is not None else self._m
        layer = _jitted_layer(self.k, self._kind, self._model, m_out,
                              self._use_kernels)
        y = layer(self._phi, self._sigma, self._state.dev, x, start, stop)
        n_cols = int(np.prod(x.shape[:-1])) if x.ndim > 1 else 1
        self._stats.charge("serve", probe_cost(stop - start, n_cols))
        return y

    def forward_many(self, xs, category: str = "probe", *,
                     block_range=None) -> list:
        """Coalesced probe sweep: N same-shape ``forward`` ops in ONE
        compiled (vmapped) call — the data plane of a batched health
        sweep.  Bit-identical to N sequential :meth:`forward` calls
        (asserted by the conformance suite); each op is charged
        individually.  Returns host arrays (one per op).

        ``xs`` is a sequence of same-shape per-op arrays, or the
        equivalent already-stacked (n, ...) array — the form a v4 batch
        frame carries, accepted directly to skip n re-conversions."""
        return list(self.forward_many_stacked(xs, category,
                                              block_range=block_range))

    def forward_many_stacked(self, xs, category: str = "probe", *,
                             block_range=None) -> np.ndarray:
        """:meth:`forward_many` without the final split: returns the
        single stacked ``(n, ...)`` host array — exactly the v4 wire
        form — so a server answering a coalesced probe span avoids
        splitting into n views only to re-stack them for the frame."""
        if isinstance(xs, np.ndarray):
            xs = np.ascontiguousarray(xs, np.float32)
        else:
            xs = np.stack([np.asarray(x, np.float32) for x in xs])
        start, stop = resolve_block_range(self._b, block_range)
        ys = np.asarray(self._jit_forward_many(
            self._phi, self._sigma, self._state.dev, xs, start, stop))
        for x in xs:
            self._stats.charge(category, probe_cost(stop - start, x.shape[0]))
        return ys

    def run_batch(self, ops):
        """Sequential dispatch, with consecutive same-shape ``forward``
        ops coalesced through :meth:`forward_many` (results and meter
        charges are bit-identical to plain sequential execution; the
        merge rule is the shared ``driver.coalesce_spans``).

        ``forward`` results are HOST (numpy) arrays whether or not the
        op happened to coalesce with its neighbors — matching the
        stream transports — so a result's type never depends on an
        invisible batching detail."""
        validate_batch_ops(ops)
        keys = [forward_coalesce_key(kw) if name == "forward" else None
                for name, kw in ops]
        out = []
        for i, j in coalesce_spans(keys):
            if j - i > 1:
                kw = ops[i][1]
                out.extend(self.forward_many(
                    [k.get("x") for _, k in ops[i:j]],
                    category=kw.get("category", "probe"),
                    block_range=kw.get("block_range")))
            else:
                res = super().run_batch([ops[i]])
                if ops[i][0] == "forward":
                    res = [np.asarray(r) for r in res]
                out.extend(res)
        return out

    def readback_bases(self, cols=None, *,
                       block_range=None) -> tuple[jax.Array, jax.Array]:
        start, stop = resolve_block_range(self._b, block_range)
        u, v = self._jit_readback(self._phi, self._state.dev, start, stop)
        if cols is not None:
            idx = jnp.asarray(cols, jnp.int32)
            u, v = u[..., :, idx], v[..., :, idx]
            self._stats.charge("readback",
                               readback_cost(stop - start, int(idx.shape[0])))
        else:
            self._stats.charge("readback",
                               readback_cost(stop - start, self.k))
        return u, v

    # -- in-situ jobs --------------------------------------------------------

    def zo_refine(self, w_blocks: jax.Array, key: jax.Array, cfg: ZOConfig,
                  method: str = "zcd", *, block_range=None) -> ZORefineResult:
        start, stop, phi, sigma, dev = self._slice(block_range)
        res = jobs.phase_refine(self._spec, self._model, dev, phi, sigma,
                                jnp.asarray(w_blocks, jnp.float32), key,
                                cfg, method)
        self._phi = res.x if stop - start == self._b else \
            self._phi.at[start:stop].set(res.x)
        # each ZCD step issues ≤2 transfer-matrix evaluations of k columns
        self._stats.charge("search",
                           float(cfg.steps * 2 * (stop - start) * self.k))
        return ZORefineResult(phi=res.x, loss=res.f, history=res.history,
                              steps=int(cfg.steps))

    def run_ic(self, key: jax.Array, sigs: jax.Array, cfg: ZOConfig, *,
               restarts: int = 4, method: str = "zcd") -> ICJobResult:
        sigs = jnp.asarray(sigs, jnp.float32)
        phi, loss, history = jobs.ic_search(
            self._spec, self._model, self._state.dev, key, cfg, sigs,
            method, restarts)
        self._phi = phi
        t = self._spec.n_rot
        u, v = realized_unitaries(self._spec, phi[:, :t], phi[:, t:],
                                  self._state.dev, self._model)
        # one surrogate measurement = k unit-vector probes per Σ_cal
        # setting; ZCD spends ≤2 measurements per step
        self._stats.charge("search", float(
            restarts * cfg.steps * 2 * sigs.shape[0] * self.k * self._b))
        self._stats.charge("readback", readback_cost(self._b, self.k))
        return ICJobResult(phi=phi, u=u, v=v, loss=loss, history=history)

    # -- time ----------------------------------------------------------------

    def advance(self, dt: float = 1.0) -> None:
        if self._drift_cfg is None:
            return
        self._drift_key, sub = jax.random.split(self._drift_key)
        self._state = advance(self._state, dt, sub, self._drift_cfg)

    # -- accounting / escape hatch -------------------------------------------

    @property
    def stats(self) -> DriverStats:
        return self._stats

    def charge(self, category: str, calls: float) -> None:
        self._stats.charge(category, calls)

    def unsafe_twin(self) -> TwinHandle:
        return TwinHandle(self)


def make_twin(key: jax.Array, n_blocks: int, k: int, model: NoiseModel,
              kind: str = "clements", *, m: int | None = None,
              n: int | None = None, drift: DriftConfig | None = None,
              dev: DeviceRealization | None = None,
              use_kernels: bool | None = None) -> TwinDriver:
    """Sample a fresh device (or wrap ``dev``) behind a TwinDriver.

    ``key`` feeds ``sample_device`` exactly as the pre-driver code did
    (seed-stable with the legacy IC/PM paths); the drift chain derives
    from the same key so one seed pins the whole chip trajectory.
    ``use_kernels`` forces the Pallas forward routing on/off (default:
    auto — on for TPU backends).
    """
    if dev is None:
        dev = sample_device(key, (n_blocks,), k, model, kind)
    return TwinDriver(dev, k, model, kind, m=m, n=n, drift=drift,
                      drift_key=jax.random.fold_in(key, 0x0D21F7),
                      use_kernels=use_kernels)
