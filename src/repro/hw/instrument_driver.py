"""`ReferenceInstrumentDriver`: the ABC minus ``unsafe_twin()``.

Proof that the control-plane surface is hardware-realizable: a driver
skeleton that implements EVERY :class:`~repro.hw.driver.PhotonicDriver`
contract — geometry, commanded-state mirror, tenant ``block_range``
validation, Appendix-G PTC metering (bit-matching the twin's charge
formulas), batching, the clock — while delegating the handful of
operations that actually touch light to abstract ``_hw_*`` hooks.  An
instrument integrator subclasses this, fills in the hooks against their
lab I/O (DAC writes, detector reads, the device's local ZO controller),
and the entire stack above the ABC — calibration, mapping, monitoring,
recalibration, fleet serving, the wire server — runs against real
hardware unchanged.

What the skeleton deliberately does NOT provide is ``unsafe_twin()``:
real hardware has no inspectable internals, so the inherited hatch
raises :class:`~repro.hw.driver.TwinUnavailable` — which is the whole
point of the observability boundary (repro-lint's RPL1xx rules restrict
the hatch to diagnostics; everything load-bearing must work without it).

The commanded-state mirror is the controller's own copy of what it has
written (phases, Σ, signs): ``read_phases``/``read_sigma`` answer from
it for free, exactly as the ABC specifies — a real chip cannot read its
phases back optically any more than the paper's §3.2 model can.

Hook contract (all scoped arrays carry ``stop - start`` blocks as their
leading dim):

===========================  ============================================
``_hw_apply_phases``         commit scoped (B, T)+(B, T) phase banks
``_hw_apply_sigma``          commit scoped (B, k) attenuators
``_hw_apply_signs``          commit scoped (B, k)+(B, k) sign banks
``_hw_forward``              probe columns (n, k) → (B, n, k)
``_hw_forward_layer``        serve rows (rows, n_in) → (rows, out_dim)
``_hw_readback``             reciprocal readout → (U, V*) columns
``_hw_zo_refine``            device-local ZO job → (phi, loss, history)
``_hw_run_ic``               device-local IC job → (phi, u, v, loss,
                             history)
===========================  ============================================
"""

from __future__ import annotations

import abc

import jax
import numpy as np

from ..core import unitary as un
from .driver import (PhotonicDriver, DriverStats, ZORefineResult, ICJobResult,
                     probe_cost, readback_cost, resolve_block_range)

__all__ = ["ReferenceInstrumentDriver"]


class ReferenceInstrumentDriver(PhotonicDriver):
    """Control-plane bookkeeping for a real photonic instrument.

    Concrete in everything the paper's observability model lets a
    controller own; abstract in exactly the operations that need a
    physical chip."""

    def __init__(self, n_blocks: int, k: int, kind: str = "clements", *,
                 m: int | None = None, n: int | None = None):
        self._spec = un.mesh_spec(k, kind)
        self._kind = kind
        self._b = int(n_blocks)
        # controller-side mirror of the commanded state (the free reads)
        t = self._spec.n_rot
        self._phi = np.zeros((self._b, 2 * t), np.float32)
        self._sigma = np.ones((self._b, k), np.float32)
        self._d_u = np.ones((self._b, k), np.float32)
        self._d_v = np.ones((self._b, k), np.float32)
        # default layer geometry: a 1×B grid (calibration-style chips),
        # matching make_twin's defaults
        self._m = int(m) if m is not None else k
        self._n = int(n) if n is not None else k * self._b
        self._stats = DriverStats()
        self._clock = 0.0

    # -- physical I/O hooks (the integrator's surface) -----------------------

    @abc.abstractmethod
    def _hw_apply_phases(self, phi_u: np.ndarray, phi_v: np.ndarray,
                         start: int, stop: int) -> None:
        """Drive the phase shifters of blocks [start, stop)."""

    @abc.abstractmethod
    def _hw_apply_sigma(self, sigma: np.ndarray,
                        start: int, stop: int) -> None:
        """Drive the Σ attenuators of blocks [start, stop)."""

    @abc.abstractmethod
    def _hw_apply_signs(self, d_u: np.ndarray, d_v: np.ndarray,
                        start: int, stop: int) -> None:
        """Configure the ±1 crossings of blocks [start, stop)."""

    @abc.abstractmethod
    def _hw_forward(self, x: np.ndarray, start: int, stop: int) -> jax.Array:
        """Stream probe columns ``x`` (n, k) through blocks [start, stop);
        detector readout, (stop-start, n, k)."""

    @abc.abstractmethod
    def _hw_forward_layer(self, x: np.ndarray, start: int, stop: int,
                          out_dim: int) -> jax.Array:
        """Serve-path forward through the assembled sub-grid of blocks
        [start, stop): (rows, n_in) → (rows, out_dim)."""

    @abc.abstractmethod
    def _hw_readback(self, cols, start: int, stop: int):
        """Reciprocal-probe basis readout of blocks [start, stop):
        ``(U, V*)`` columns, each (stop-start, k, len(cols))."""

    @abc.abstractmethod
    def _hw_zo_refine(self, w_blocks: np.ndarray, key, cfg, method: str,
                      start: int, stop: int):
        """Device-local hardware-restricted ZO against per-block targets;
        returns ``(phi, loss, history)`` with phi (stop-start, 2T).  The
        skeleton commits phi to the mirror and meters the search."""

    @abc.abstractmethod
    def _hw_run_ic(self, key, sigs: np.ndarray, cfg, restarts: int,
                   method: str):
        """Device-local Identity Calibration; returns
        ``(phi, u, v, loss, history)``.  The skeleton commits phi and
        meters search + readback."""

    # -- geometry ------------------------------------------------------------

    @property
    def k(self) -> int:
        return self._spec.k

    @property
    def kind(self) -> str:
        return self._kind

    @property
    def n_blocks(self) -> int:
        return self._b

    @property
    def layer_shape(self) -> tuple[int, int]:
        return self._m, self._n

    # -- commanded state (mirror + commit) -----------------------------------

    def write_phases(self, phi_u, phi_v, *, block_range=None) -> None:
        t = self._spec.n_rot
        start, stop = resolve_block_range(self._b, block_range)
        nb = stop - start
        phi_u = np.asarray(phi_u, np.float32).reshape(nb, t)
        phi_v = np.asarray(phi_v, np.float32).reshape(nb, t)
        self._phi[start:stop, :t] = phi_u
        self._phi[start:stop, t:] = phi_v
        self._hw_apply_phases(phi_u, phi_v, start, stop)

    def write_sigma(self, sigma, *, block_range=None) -> None:
        start, stop = resolve_block_range(self._b, block_range)
        sigma = np.asarray(sigma, np.float32).reshape(stop - start, self.k)
        self._sigma[start:stop] = sigma
        self._hw_apply_sigma(sigma, start, stop)

    def write_signs(self, d_u, d_v, *, block_range=None) -> None:
        start, stop = resolve_block_range(self._b, block_range)
        nb = stop - start
        d_u = np.asarray(d_u, np.float32).reshape(nb, self.k)
        d_v = np.asarray(d_v, np.float32).reshape(nb, self.k)
        self._d_u[start:stop] = d_u
        self._d_v[start:stop] = d_v
        self._hw_apply_signs(d_u, d_v, start, stop)

    def read_phases(self):
        t = self._spec.n_rot
        return self._phi[:, :t].copy(), self._phi[:, t:].copy()

    def read_sigma(self):
        return self._sigma.copy()

    # -- probes (metered identically to the twin) ----------------------------

    def forward(self, x, category: str = "probe", *, block_range=None):
        x = np.asarray(x, np.float32)
        start, stop = resolve_block_range(self._b, block_range)
        y = self._hw_forward(x, start, stop)
        self._stats.charge(category, probe_cost(stop - start, x.shape[0]))
        return y

    def forward_layer(self, x, *, block_range=None,
                      out_dim: int | None = None):
        x = np.asarray(x, np.float32)
        start, stop = resolve_block_range(self._b, block_range)
        if out_dim is None:
            out_dim = self._m if (start, stop) == (0, self._b) else \
                (stop - start) * self.k
        lead, n_in = x.shape[:-1], x.shape[-1]
        rows = x.reshape(-1, n_in)
        y = self._hw_forward_layer(rows, start, stop, int(out_dim))
        self._stats.charge("serve", probe_cost(stop - start, rows.shape[0]))
        return np.asarray(y).reshape(*lead, int(out_dim))

    def readback_bases(self, cols=None, *, block_range=None):
        start, stop = resolve_block_range(self._b, block_range)
        if cols is not None:
            idx = [int(c) for c in np.asarray(cols).reshape(-1)]
            u, v = self._hw_readback(idx, start, stop)
            self._stats.charge("readback",
                               readback_cost(stop - start, len(idx)))
        else:
            u, v = self._hw_readback(list(range(self.k)), start, stop)
            self._stats.charge("readback", readback_cost(stop - start,
                                                         self.k))
        return u, v

    # -- in-situ jobs --------------------------------------------------------

    def zo_refine(self, w_blocks, key, cfg, method: str = "zcd", *,
                  block_range=None) -> ZORefineResult:
        start, stop = resolve_block_range(self._b, block_range)
        phi, loss, history = self._hw_zo_refine(
            np.asarray(w_blocks, np.float32), key, cfg, method, start, stop)
        self._phi[start:stop] = np.asarray(phi, np.float32)
        # each ZCD step issues ≤2 transfer-matrix evaluations of k
        # columns — the twin's exact charge formula
        self._stats.charge("search",
                           float(cfg.steps * 2 * (stop - start) * self.k))
        return ZORefineResult(phi=phi, loss=loss, history=history,
                              steps=int(cfg.steps))

    def run_ic(self, key, sigs, cfg, *, restarts: int = 4,
               method: str = "zcd") -> ICJobResult:
        sigs = np.asarray(sigs, np.float32)
        phi, u, v, loss, history = self._hw_run_ic(key, sigs, cfg,
                                                   int(restarts), method)
        self._phi[:] = np.asarray(phi, np.float32)
        # one surrogate measurement = k unit-vector probes per Σ_cal
        # setting; ZCD spends ≤2 measurements per step — twin-identical
        self._stats.charge("search", float(
            restarts * cfg.steps * 2 * sigs.shape[0] * self.k * self._b))
        self._stats.charge("readback", readback_cost(self._b, self.k))
        return ICJobResult(phi=phi, u=u, v=v, loss=loss, history=history)

    # -- time / accounting ---------------------------------------------------

    def advance(self, dt: float = 1.0) -> None:
        # real hardware drifts on its own; the controller only keeps the
        # virtual clock other bookkeeping (recal cadence) is phrased in
        self._clock += float(dt)

    @property
    def clock(self) -> float:
        """Virtual time elapsed via :meth:`advance`."""
        return self._clock

    @property
    def stats(self) -> DriverStats:
        return self._stats

    def charge(self, category: str, calls: float) -> None:
        self._stats.charge(category, calls)

    # unsafe_twin() is deliberately NOT implemented: the inherited hatch
    # raises TwinUnavailable — real hardware has no inspectable twin.
