"""Out-of-process twin server: ``python -m repro.hw.server``.

Hosts one :class:`TwinDriver` per session and serves the driver protocol
(newline-delimited JSON, see ``repro.hw.protocol``) over either

* **stdin/stdout** (the default — the :class:`SubprocessDriver` pipe
  topology), or
* **TCP** (``--socket HOST:PORT`` — the :class:`SocketDriver` topology,
  so the twin can run on another host; ``PORT=0`` binds an ephemeral
  port, announced as ``LISTENING <port>`` on stdout for self-hosted
  clients).  Connections are served one at a time, each with its own
  fresh driver session; ``--max-conns N`` exits after N sessions (the
  self-hosted lifetime).

This is the hardware-in-the-loop shape: the parent's stream driver sees
only the control-plane surface, while the device physics lives in this
process — swap this server for a real instrument daemon and nothing on
the control plane changes.

In-situ jobs (``zo_refine`` / ``run_ic``) execute *here*, against the
local device, with the same ``repro.hw.jobs`` code the in-process twin
uses — so results are bit-identical across transports for equal seeds
(same functions, same backend), which the conformance suite asserts.

The v3 ``batch`` op executes an ordered sub-op list in one round-trip:
each sub-op dispatches through exactly the same code as a standalone
frame, so batched ≡ sequential bit-identically and every op is metered
individually (one batch ≠ one PTC call).  A failing sub-op aborts the
remainder; the error names its index.

The ``unsafe/*`` ops back the parent's ``unsafe_twin()`` escape hatch;
they exist because this peer happens to be a simulator.  A real-hardware
daemon would simply not implement them.
"""

from __future__ import annotations

import argparse
import socket as _socket
import sys
import traceback

import jax.numpy as jnp
import numpy as np

from ..core.noise import NoiseModel
from ..optim.zo import ZOConfig
from .drift import DriftConfig
from .driver import forward_coalesce_key, coalesce_spans, BATCHABLE_OPS
from .protocol import (encode, decode, send, recv, ProtocolError,
                       PROTOCOL_VERSION)
from .twin import make_twin

__all__ = ["serve", "serve_socket", "main"]


def _build_driver(kw: dict):
    v = int(kw.get("v", 1))
    if v != PROTOCOL_VERSION:
        raise RuntimeError(
            f"driver protocol mismatch: client speaks v{v}, server "
            f"speaks v{PROTOCOL_VERSION}")
    model = NoiseModel(**kw["model"])
    drift = DriftConfig(**kw["drift"]) if kw.get("drift") else None
    return make_twin(jnp.asarray(kw["key"]), int(kw["n_blocks"]),
                     int(kw["k"]), model, kw.get("kind", "clements"),
                     m=kw.get("m"), n=kw.get("n"), drift=drift)


def _rng(kw: dict):
    br = kw.get("block_range")
    return tuple(int(i) for i in br) if br is not None else None


def _dispatch(driver, op: str, kw: dict):
    if op == "batch":
        # ordered sub-op list, one round-trip; each sub-op goes through
        # this same dispatcher (same results, same per-op metering),
        # except that consecutive same-shape probe ``forward`` ops
        # coalesce into ONE vmapped device call (bit-identical results,
        # per-op charges — the probe-sweep fast path)
        entries = kw.get("ops") or []
        for entry in entries:
            # the same whitelist PhotonicDriver.run_batch enforces
            # in-process: session-control ops can't nest, and the
            # unsafe/* twin hatch and meta stay out of reach of batch
            # frames from untrusted wire peers
            if entry.get("op") not in BATCHABLE_OPS:
                raise ValueError(
                    f"op {entry.get('op')!r} cannot appear inside a batch")
        can_coalesce = hasattr(driver, "forward_many")
        keys = [forward_coalesce_key(e.get("kw") or {})
                if can_coalesce and e.get("op") == "forward" else None
                for e in entries]
        results = []
        for i, j in coalesce_spans(keys):
            sub = entries[i].get("op")
            try:
                if j - i > 1:
                    kw_i = entries[i].get("kw") or {}
                    ys = driver.forward_many(
                        [(e.get("kw") or {})["x"] for e in entries[i:j]],
                        category=kw_i.get("category", "probe"),
                        block_range=_rng(kw_i))
                    # the span travels as ONE stacked array (op axis
                    # leading) — one codec pass instead of n; the client
                    # splits it back into per-op results, bit-identical
                    results.append(dict(coalesced=j - i, y=np.stack(ys)))
                else:
                    results.append(
                        _dispatch(driver, sub, entries[i].get("kw") or {}))
            except Exception as e:
                raise RuntimeError(
                    f"batch op {i} ({sub!r}) failed: {e}\n"
                    f"(ops [0, {i}) were already applied)") from e
        return results
    if op == "meta":
        m, n = driver.layer_shape
        return dict(k=driver.k, kind=driver.kind, n_blocks=driver.n_blocks,
                    m=m, n=n, v=PROTOCOL_VERSION)
    if op == "write_phases":
        driver.write_phases(kw["phi_u"], kw["phi_v"], block_range=_rng(kw))
        return None
    if op == "write_sigma":
        driver.write_sigma(kw["sigma"], block_range=_rng(kw))
        return None
    if op == "write_signs":
        driver.write_signs(kw["d_u"], kw["d_v"], block_range=_rng(kw))
        return None
    if op == "read_phases":
        phi_u, phi_v = driver.read_phases()
        return dict(phi_u=phi_u, phi_v=phi_v)
    if op == "read_sigma":
        return dict(sigma=driver.read_sigma())
    if op == "forward":
        return dict(y=driver.forward(kw["x"], kw.get("category", "probe"),
                                     block_range=_rng(kw)))
    if op == "forward_layer":
        out_dim = kw.get("out_dim")
        return dict(y=driver.forward_layer(
            kw["x"], block_range=_rng(kw),
            out_dim=int(out_dim) if out_dim is not None else None))
    if op == "readback_bases":
        u, v = driver.readback_bases(cols=kw.get("cols"),
                                     block_range=_rng(kw))
        return dict(u=u, v=v)
    if op == "zo_refine":
        res = driver.zo_refine(kw["w_blocks"], jnp.asarray(kw["key"]),
                               ZOConfig(**kw["cfg"]),
                               method=kw.get("method", "zcd"),
                               block_range=_rng(kw))
        return dict(phi=res.phi, loss=res.loss, history=res.history,
                    steps=res.steps)
    if op == "run_ic":
        res = driver.run_ic(jnp.asarray(kw["key"]), kw["sigs"],
                            ZOConfig(**kw["cfg"]),
                            restarts=int(kw.get("restarts", 4)),
                            method=kw.get("method", "zcd"))
        return dict(phi=res.phi, u=res.u, v=res.v, loss=res.loss,
                    history=res.history)
    if op == "advance":
        driver.advance(float(kw.get("dt", 1.0)))
        return None
    if op == "stats":
        return driver.stats.as_dict()
    if op == "reset_stats":
        driver.reset_stats()
        return None
    if op == "charge":
        driver.charge(kw["category"], float(kw["calls"]))
        return None
    # -- unsafe/* : twin-internal readouts backing unsafe_twin() -------------
    if op == "unsafe/true_mapping_distance":
        return dict(d=driver.unsafe_twin().true_mapping_distance(
            jnp.asarray(kw["w_blocks"]), block_range=_rng(kw)))
    if op == "unsafe/bias_deviation":
        return dict(d=driver.unsafe_twin().bias_deviation())
    if op == "unsafe/dev":
        dev = driver.unsafe_twin().dev
        return dict(gamma_u=dev.noise_u.gamma, bias_u=dev.noise_u.bias,
                    gamma_v=dev.noise_v.gamma, bias_v=dev.noise_v.bias,
                    d_u=dev.d_u, d_v=dev.d_v)
    if op == "unsafe/realized_unitaries":
        u, v = driver.unsafe_twin().realized_unitaries()
        return dict(u=u, v=v)
    raise ValueError(f"unknown op: {op!r}")


def serve(fin, fout) -> None:
    """One driver session over a newline-JSON stream pair.

    Returns when the peer shuts down, disconnects, or desyncs the
    framing (malformed/oversized frames are rejected with a best-effort
    error frame, then the connection is dropped — after a framing
    violation the stream position is untrustworthy)."""
    driver = None
    while True:
        try:
            req = recv(fin)
        except ProtocolError as e:
            if "closed" not in str(e):
                # framing violation (not a clean disconnect): reject
                # loudly before dropping the connection
                try:
                    send(fout, dict(id=None, ok=False,
                                    error=f"protocol error: {e}"))
                except Exception:
                    pass
            return
        rid = None
        try:
            # inside the try: a valid-JSON frame can still be a non-dict
            # or carry a malformed __nd__ payload — that must draw an
            # error frame, not escape serve() (and, for the socket
            # daemon, kill the session loop for every future client)
            rid, op = req.get("id"), req.get("op")
            kw = decode(req.get("kw") or {})
            if op == "shutdown":
                send(fout, dict(id=rid, ok=True, result=None))
                return
            if op == "init":
                driver = _build_driver(kw)
                result = _dispatch(driver, "meta", {})
            elif driver is None:
                raise RuntimeError("first op must be 'init'")
            else:
                result = _dispatch(driver, op, kw)
            try:
                send(fout, dict(id=rid, ok=True, result=encode(result)))
            except ProtocolError as e:
                # result too large for one frame: send() refused BEFORE
                # writing, so the stream is still framed — report a
                # per-op error and keep the session (the op's state
                # effects stand, exactly as a failed read would)
                send(fout, dict(id=rid, ok=False,
                                error=f"result not sendable: {e}"))
        except ProtocolError:
            return                      # response no longer sendable
        except OSError:
            return                      # transport died mid-response
        except Exception:
            send(fout, dict(id=rid, ok=False,
                            error=traceback.format_exc(limit=8)))


def serve_socket(host: str = "127.0.0.1", port: int = 0, *,
                 max_conns: int | None = None, announce=None) -> None:
    """Serve driver sessions over TCP, one connection at a time.

    Each accepted connection is an independent session (own init, own
    TwinDriver).  ``port=0`` binds an ephemeral port; the bound port is
    announced as ``LISTENING <port>`` on ``announce`` (default stdout)
    so self-hosting clients can discover it.  ``max_conns`` bounds the
    number of sessions served (None = forever).
    """
    out = announce if announce is not None else sys.stdout
    with _socket.create_server((host, port)) as srv:
        print(f"LISTENING {srv.getsockname()[1]}", file=out, flush=True)
        served = 0
        while max_conns is None or served < max_conns:
            conn, peer = srv.accept()
            with conn:
                conn.setsockopt(_socket.IPPROTO_TCP, _socket.TCP_NODELAY, 1)
                fin = conn.makefile("r", encoding="utf-8", newline="\n",
                                    buffering=1 << 20)
                fout = conn.makefile("w", encoding="utf-8", newline="\n",
                                     buffering=1 << 20)
                try:
                    serve(fin, fout)
                except OSError as e:
                    # one client dying mid-session (BrokenPipe on send,
                    # RST on recv) must not take the daemon down with it
                    print(f"session from {peer} aborted: {e}",
                          file=sys.stderr, flush=True)
                finally:
                    try:
                        fout.flush()
                    except Exception:
                        pass
            served += 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="repro.hw twin server (op-stream driver protocol v3)")
    ap.add_argument("--socket", metavar="HOST:PORT", default=None,
                    help="serve over TCP instead of stdin/stdout "
                         "(PORT=0 picks an ephemeral port)")
    ap.add_argument("--max-conns", type=int, default=None,
                    help="exit after N socket sessions (default: serve "
                         "forever)")
    args = ap.parse_args(argv)
    if args.socket is not None:
        host, _, port = args.socket.rpartition(":")
        serve_socket(host or "127.0.0.1", int(port),
                     max_conns=args.max_conns)
        return 0
    # stdout is the wire: anything else (jax chatter) must go to stderr
    serve(sys.stdin, sys.stdout)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
