"""Out-of-process twin server: ``python -m repro.hw.server``.

Hosts one :class:`TwinDriver` and serves the driver protocol over
stdin/stdout (newline-delimited JSON, see ``repro.hw.protocol``).  This
is the hardware-in-the-loop shape: the parent's
:class:`SubprocessDriver` sees only the control-plane surface, while the
device physics lives in this process — swap this server for a real
instrument daemon and nothing on the control plane changes.

In-situ jobs (``zo_refine`` / ``run_ic``) execute *here*, against the
local device, with the same ``repro.hw.jobs`` code the in-process twin
uses — so results are bit-identical across transports for equal seeds
(same functions, same backend), which the conformance suite asserts.

The ``unsafe/*`` ops back the parent's ``unsafe_twin()`` escape hatch;
they exist because this peer happens to be a simulator.  A real-hardware
daemon would simply not implement them.
"""

from __future__ import annotations

import sys
import traceback

import jax.numpy as jnp

from ..core.noise import NoiseModel
from ..optim.zo import ZOConfig
from .drift import DriftConfig
from .protocol import (encode, decode, send, recv, ProtocolError,
                       PROTOCOL_VERSION)
from .twin import make_twin

__all__ = ["serve", "main"]


def _build_driver(kw: dict):
    v = int(kw.get("v", 1))
    if v != PROTOCOL_VERSION:
        raise RuntimeError(
            f"driver protocol mismatch: client speaks v{v}, server "
            f"speaks v{PROTOCOL_VERSION}")
    model = NoiseModel(**kw["model"])
    drift = DriftConfig(**kw["drift"]) if kw.get("drift") else None
    return make_twin(jnp.asarray(kw["key"]), int(kw["n_blocks"]),
                     int(kw["k"]), model, kw.get("kind", "clements"),
                     m=kw.get("m"), n=kw.get("n"), drift=drift)


def _rng(kw: dict):
    br = kw.get("block_range")
    return tuple(int(i) for i in br) if br is not None else None


def _dispatch(driver, op: str, kw: dict):
    if op == "meta":
        m, n = driver.layer_shape
        return dict(k=driver.k, kind=driver.kind, n_blocks=driver.n_blocks,
                    m=m, n=n, v=PROTOCOL_VERSION)
    if op == "write_phases":
        driver.write_phases(kw["phi_u"], kw["phi_v"], block_range=_rng(kw))
        return None
    if op == "write_sigma":
        driver.write_sigma(kw["sigma"], block_range=_rng(kw))
        return None
    if op == "write_signs":
        driver.write_signs(kw["d_u"], kw["d_v"], block_range=_rng(kw))
        return None
    if op == "read_phases":
        phi_u, phi_v = driver.read_phases()
        return dict(phi_u=phi_u, phi_v=phi_v)
    if op == "read_sigma":
        return dict(sigma=driver.read_sigma())
    if op == "forward":
        return dict(y=driver.forward(kw["x"], kw.get("category", "probe"),
                                     block_range=_rng(kw)))
    if op == "forward_layer":
        out_dim = kw.get("out_dim")
        return dict(y=driver.forward_layer(
            kw["x"], block_range=_rng(kw),
            out_dim=int(out_dim) if out_dim is not None else None))
    if op == "readback_bases":
        u, v = driver.readback_bases(cols=kw.get("cols"),
                                     block_range=_rng(kw))
        return dict(u=u, v=v)
    if op == "zo_refine":
        res = driver.zo_refine(kw["w_blocks"], jnp.asarray(kw["key"]),
                               ZOConfig(**kw["cfg"]),
                               method=kw.get("method", "zcd"),
                               block_range=_rng(kw))
        return dict(phi=res.phi, loss=res.loss, history=res.history,
                    steps=res.steps)
    if op == "run_ic":
        res = driver.run_ic(jnp.asarray(kw["key"]), kw["sigs"],
                            ZOConfig(**kw["cfg"]),
                            restarts=int(kw.get("restarts", 4)),
                            method=kw.get("method", "zcd"))
        return dict(phi=res.phi, u=res.u, v=res.v, loss=res.loss,
                    history=res.history)
    if op == "advance":
        driver.advance(float(kw.get("dt", 1.0)))
        return None
    if op == "stats":
        return driver.stats.as_dict()
    if op == "reset_stats":
        driver.reset_stats()
        return None
    if op == "charge":
        driver.charge(kw["category"], float(kw["calls"]))
        return None
    # -- unsafe/* : twin-internal readouts backing unsafe_twin() -------------
    if op == "unsafe/true_mapping_distance":
        return dict(d=driver.unsafe_twin().true_mapping_distance(
            jnp.asarray(kw["w_blocks"]), block_range=_rng(kw)))
    if op == "unsafe/bias_deviation":
        return dict(d=driver.unsafe_twin().bias_deviation())
    if op == "unsafe/dev":
        dev = driver.unsafe_twin().dev
        return dict(gamma_u=dev.noise_u.gamma, bias_u=dev.noise_u.bias,
                    gamma_v=dev.noise_v.gamma, bias_v=dev.noise_v.bias,
                    d_u=dev.d_u, d_v=dev.d_v)
    if op == "unsafe/realized_unitaries":
        u, v = driver.unsafe_twin().realized_unitaries()
        return dict(u=u, v=v)
    raise ValueError(f"unknown op: {op!r}")


def serve(fin, fout) -> None:
    driver = None
    while True:
        try:
            req = recv(fin)
        except ProtocolError:
            return                      # parent went away: exit quietly
        rid, op = req.get("id"), req.get("op")
        kw = decode(req.get("kw") or {})
        try:
            if op == "shutdown":
                send(fout, dict(id=rid, ok=True, result=None))
                return
            if op == "init":
                driver = _build_driver(kw)
                result = _dispatch(driver, "meta", {})
            elif driver is None:
                raise RuntimeError("first op must be 'init'")
            else:
                result = _dispatch(driver, op, kw)
            send(fout, dict(id=rid, ok=True, result=encode(result)))
        except Exception:
            send(fout, dict(id=rid, ok=False,
                            error=traceback.format_exc(limit=8)))


def main() -> int:
    # stdout is the wire: anything else (jax chatter) must go to stderr
    serve(sys.stdin, sys.stdout)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
