"""Out-of-process twin server: ``python -m repro.hw.server``.

Hosts one :class:`TwinDriver` per session and serves the driver protocol
(v4 binary frames with a v3 JSON-line fallback, see
``repro.hw.protocol``) over either

* **stdin/stdout** (the default — the :class:`SubprocessDriver` pipe
  topology), or
* **TCP** (``--socket HOST:PORT`` — the :class:`SocketDriver` topology,
  so the twin can run on another host; ``PORT=0`` binds an ephemeral
  port, announced as ``LISTENING <port>`` on stdout for self-hosted
  clients).  Connections are served **concurrently**, one thread and one
  fresh driver session per connection — one twin-farm process can serve
  a whole fleet.  ``--max-conns N`` bounds how many sessions run at
  once (further accepts wait); ``--sessions N`` exits after N sessions
  total (the self-hosted lifetime).

This is the hardware-in-the-loop shape: the parent's stream driver sees
only the control-plane surface, while the device physics lives in this
process — swap this server for a real instrument daemon and nothing on
the control plane changes.

Version negotiation: the client's ``init`` frame (always a JSON line)
carries ``v``; the server accepts any of ``SUPPORTED_VERSIONS`` and
echoes the negotiated version in the init result.  The init exchange
itself always travels as JSON lines; once v4 is negotiated, both sides
switch the session to binary frames.  A v3 peer keeps JSON lines for
the whole session — bit-identical results either way.

In-situ jobs (``zo_refine`` / ``run_ic``) execute *here*, against the
local device, with the same ``repro.hw.jobs`` code the in-process twin
uses — so results are bit-identical across transports for equal seeds
(same functions, same backend), which the conformance suite asserts.

The v3 ``batch`` op executes an ordered sub-op list in one round-trip:
each sub-op dispatches through exactly the same code as a standalone
frame, so batched ≡ sequential bit-identically and every op is metered
individually (one batch ≠ one PTC call).  A failing sub-op aborts the
remainder; the error names its index.

The ``unsafe/*`` ops back the parent's ``unsafe_twin()`` escape hatch;
they exist because this peer happens to be a simulator.  A real-hardware
daemon would simply not implement them.
"""

from __future__ import annotations

import argparse
import socket as _socket
import sys
import threading
import traceback

import jax.numpy as jnp
import numpy as np

from ..core.noise import NoiseModel
from ..optim.zo import ZOConfig
from .drift import DriftConfig
from .driver import (forward_coalesce_key, coalesce_spans, BATCHABLE_OPS,
                     WIRE_INTERNAL_OPS)
from .protocol import (encode, decode, send, recv, ProtocolError,
                       PROTOCOL_VERSION, SUPPORTED_VERSIONS)
from .twin import make_twin

__all__ = ["serve", "serve_socket", "main"]


def _build_driver(kw: dict):
    """Build the session driver from an ``init`` payload.

    Returns ``(driver, negotiated_version)``.  Any version outside
    ``SUPPORTED_VERSIONS`` is a hard mismatch — the error string keeps
    the ``protocol mismatch`` marker the v4 client's fallback logic
    keys on."""
    v = int(kw.get("v", 1))
    if v not in SUPPORTED_VERSIONS:
        supported = "/".join(f"v{s}" for s in SUPPORTED_VERSIONS)
        raise RuntimeError(
            f"driver protocol mismatch: client speaks v{v}, server "
            f"speaks {supported}")
    model = NoiseModel(**kw["model"])
    drift = DriftConfig(**kw["drift"]) if kw.get("drift") else None
    return make_twin(jnp.asarray(kw["key"]), int(kw["n_blocks"]),
                     int(kw["k"]), model, kw.get("kind", "clements"),
                     m=kw.get("m"), n=kw.get("n"), drift=drift), v


def _rng(kw: dict):
    br = kw.get("block_range")
    return tuple(int(i) for i in br) if br is not None else None


def _dispatch(driver, op: str, kw: dict):
    if op == "batch":
        # ordered sub-op list, one round-trip; each sub-op goes through
        # this same dispatcher (same results, same per-op metering),
        # except that consecutive same-shape probe ``forward`` ops
        # coalesce into ONE vmapped device call (bit-identical results,
        # per-op charges — the probe-sweep fast path)
        entries = kw.get("ops") or []
        for entry in entries:
            # the same whitelist PhotonicDriver.run_batch enforces
            # in-process — plus "forward_many", the wire-internal form a
            # v4 client ships when it coalesces a probe span before
            # encoding; session-control ops can't nest, and the unsafe/*
            # twin hatch and meta stay out of reach of batch frames from
            # untrusted wire peers
            if entry.get("op") not in BATCHABLE_OPS \
                    and entry.get("op") not in WIRE_INTERNAL_OPS:
                raise ValueError(
                    f"op {entry.get('op')!r} cannot appear inside a batch")
        can_coalesce = hasattr(driver, "forward_many")
        keys = [forward_coalesce_key(e.get("kw") or {})
                if can_coalesce and e.get("op") == "forward" else None
                for e in entries]
        results = []
        for i, j in coalesce_spans(keys):
            sub = entries[i].get("op")
            try:
                if j - i > 1:
                    kw_i = entries[i].get("kw") or {}
                    xs_span = [(e.get("kw") or {})["x"]
                               for e in entries[i:j]]
                    # the span travels as ONE stacked array (op axis
                    # leading) — one codec pass instead of n; the client
                    # splits it back into per-op results, bit-identical
                    fm = getattr(driver, "forward_many_stacked", None)
                    if fm is not None:
                        y = fm(xs_span,
                               category=kw_i.get("category", "probe"),
                               block_range=_rng(kw_i))
                    else:
                        y = np.stack(driver.forward_many(
                            xs_span,
                            category=kw_i.get("category", "probe"),
                            block_range=_rng(kw_i)))
                    results.append(dict(coalesced=j - i, y=y))
                else:
                    results.append(
                        _dispatch(driver, sub, entries[i].get("kw") or {}))
            except Exception as e:
                raise RuntimeError(
                    f"batch op {i} ({sub!r}) failed: {e}\n"
                    f"(ops [0, {i}) were already applied)") from e
        return results
    if op == "meta":
        m, n = driver.layer_shape
        return dict(k=driver.k, kind=driver.kind, n_blocks=driver.n_blocks,
                    m=m, n=n, v=PROTOCOL_VERSION)
    if op == "write_phases":
        driver.write_phases(kw["phi_u"], kw["phi_v"], block_range=_rng(kw))
        return None
    if op == "write_sigma":
        driver.write_sigma(kw["sigma"], block_range=_rng(kw))
        return None
    if op == "write_signs":
        driver.write_signs(kw["d_u"], kw["d_v"], block_range=_rng(kw))
        return None
    if op == "read_phases":
        phi_u, phi_v = driver.read_phases()
        return dict(phi_u=phi_u, phi_v=phi_v)
    if op == "read_sigma":
        return dict(sigma=driver.read_sigma())
    if op == "forward":
        return dict(y=driver.forward(kw["x"], kw.get("category", "probe"),
                                     block_range=_rng(kw)))
    if op == "forward_many":
        # a client-coalesced probe span: one stacked x array in, one
        # stacked y out (the same shape the server's own batch
        # coalescing emits, so the client splits both identically)
        xs = kw["xs"]
        cat = kw.get("category", "probe")
        fm = getattr(driver, "forward_many_stacked", None)
        if fm is not None:
            y = fm(xs, category=cat, block_range=_rng(kw))
            return dict(coalesced=int(y.shape[0]), y=y)
        if hasattr(driver, "forward_many"):
            ys = driver.forward_many(xs, category=cat, block_range=_rng(kw))
        else:
            ys = [driver.forward(x, cat, block_range=_rng(kw)) for x in xs]
        return dict(coalesced=len(ys),
                    y=np.stack([np.asarray(y) for y in ys]))
    if op == "forward_layer":
        out_dim = kw.get("out_dim")
        return dict(y=driver.forward_layer(
            kw["x"], block_range=_rng(kw),
            out_dim=int(out_dim) if out_dim is not None else None))
    if op == "readback_bases":
        u, v = driver.readback_bases(cols=kw.get("cols"),
                                     block_range=_rng(kw))
        return dict(u=u, v=v)
    if op == "zo_refine":
        res = driver.zo_refine(kw["w_blocks"], jnp.asarray(kw["key"]),
                               ZOConfig(**kw["cfg"]),
                               method=kw.get("method", "zcd"),
                               block_range=_rng(kw))
        return dict(phi=res.phi, loss=res.loss, history=res.history,
                    steps=res.steps)
    if op == "run_ic":
        res = driver.run_ic(jnp.asarray(kw["key"]), kw["sigs"],
                            ZOConfig(**kw["cfg"]),
                            restarts=int(kw.get("restarts", 4)),
                            method=kw.get("method", "zcd"))
        return dict(phi=res.phi, u=res.u, v=res.v, loss=res.loss,
                    history=res.history)
    if op == "advance":
        driver.advance(float(kw.get("dt", 1.0)))
        return None
    if op == "stats":
        return driver.stats.as_dict()
    if op == "reset_stats":
        driver.reset_stats()
        return None
    if op == "charge":
        driver.charge(kw["category"], float(kw["calls"]))
        return None
    # -- unsafe/* : twin-internal readouts backing unsafe_twin() -------------
    if op == "unsafe/true_mapping_distance":
        return dict(d=driver.unsafe_twin().true_mapping_distance(
            jnp.asarray(kw["w_blocks"]), block_range=_rng(kw)))
    if op == "unsafe/bias_deviation":
        return dict(d=driver.unsafe_twin().bias_deviation())
    if op == "unsafe/dev":
        dev = driver.unsafe_twin().dev
        return dict(gamma_u=dev.noise_u.gamma, bias_u=dev.noise_u.bias,
                    gamma_v=dev.noise_v.gamma, bias_v=dev.noise_v.bias,
                    d_u=dev.d_u, d_v=dev.d_v)
    if op == "unsafe/realized_unitaries":
        u, v = driver.unsafe_twin().realized_unitaries()
        return dict(u=u, v=v)
    raise ValueError(f"unknown op: {op!r}")


def serve(fin, fout) -> None:
    """One driver session over a byte-stream pair.

    Frames arrive in either encoding (:func:`recv` auto-detects); the
    session's *outbound* encoding follows the init handshake — JSON
    lines until (and including) the init reply, binary once v4 is
    negotiated.  Returns when the peer shuts down, disconnects, or
    desyncs the framing (malformed/oversized frames are rejected with a
    best-effort error frame, then the connection is dropped — after a
    framing violation the stream position is untrustworthy)."""
    driver = None
    binary = False
    while True:
        try:
            req = recv(fin)
        except ProtocolError as e:
            if "closed" not in str(e):
                # framing violation (not a clean disconnect): reject
                # loudly before dropping the connection
                try:
                    send(fout, dict(id=None, ok=False,
                                    error=f"protocol error: {e}"),
                         binary=binary)
                except Exception:
                    pass
            return
        rid = None
        try:
            # inside the try: a valid frame can still be a non-dict
            # or carry a malformed __nd__ payload — that must draw an
            # error frame, not escape serve() (and, for the socket
            # daemon, kill the session loop for every future client)
            rid, op = req.get("id"), req.get("op")
            kw = decode(req.get("kw") or {})
            if op == "shutdown":
                send(fout, dict(id=rid, ok=True, result=None), binary=binary)
                return
            if op == "init":
                driver, v = _build_driver(kw)
                result = _dispatch(driver, "meta", {})
                result["v"] = v         # echo the NEGOTIATED version
                # the init reply always travels as a JSON line (the
                # peer only switches framing after reading it) …
                send(fout, dict(id=rid, ok=True, result=encode(result)))
                # … then the session goes binary iff v4 was negotiated
                binary = v >= 4
                continue
            elif driver is None:
                raise RuntimeError("first op must be 'init'")
            else:
                result = _dispatch(driver, op, kw)
            try:
                send(fout, dict(id=rid, ok=True,
                                result=encode(result, binary=binary)),
                     binary=binary)
            except ProtocolError as e:
                # result too large for one frame: send() refused BEFORE
                # writing, so the stream is still framed — report a
                # per-op error and keep the session (the op's state
                # effects stand, exactly as a failed read would)
                send(fout, dict(id=rid, ok=False,
                                error=f"result not sendable: {e}"),
                     binary=binary)
        except ProtocolError:
            return                      # response no longer sendable
        except OSError:
            return                      # transport died mid-response
        except Exception:
            send(fout, dict(id=rid, ok=False,
                            error=traceback.format_exc(limit=8)),
                 binary=binary)


def _serve_connection(conn, peer, lock: threading.Lock, state: dict,
                      gate) -> None:
    """One socket session, fully contained: ANY exception escaping the
    session (not just OSError — e.g. a MemoryError from a hostile frame,
    or a dispatcher bug outside serve()'s per-frame try) is logged and
    swallowed so the daemon keeps serving other clients.  Accounting
    (``served``) increments either way, under the shared lock."""
    try:
        try:
            with conn:
                conn.setsockopt(_socket.IPPROTO_TCP, _socket.TCP_NODELAY, 1)
                fin = conn.makefile("rb", buffering=1 << 20)
                fout = conn.makefile("wb", buffering=1 << 20)
                try:
                    serve(fin, fout)
                finally:
                    try:
                        fout.flush()
                    except Exception:
                        pass
        except Exception as e:
            # one client dying mid-session (BrokenPipe on send, RST on
            # recv) — or a non-OSError bug in its session — must not
            # take the daemon down with it
            print(f"session from {peer} aborted: {e!r}",
                  file=sys.stderr, flush=True)
    finally:
        with lock:
            state["served"] += 1
        if gate is not None:
            gate.release()


def serve_socket(host: str = "127.0.0.1", port: int = 0, *,
                 max_conns: int | None = None,
                 sessions: int | None = None, announce=None) -> None:
    """Serve driver sessions over TCP, one thread per connection.

    Each accepted connection is an independent concurrent session (own
    init, own TwinDriver, own thread); shared state is only the
    announce stream and the ``served`` counter, guarded by one lock.
    ``port=0`` binds an ephemeral port; the bound port is announced as
    ``LISTENING <port>`` on ``announce`` (default stdout) so
    self-hosting clients can discover it.

    ``max_conns`` is the *concurrency* budget — at most that many
    sessions run at once, further accepts queue in the listen backlog.
    ``sessions`` bounds the daemon lifetime: stop accepting after that
    many sessions total, drain the live ones, return.  (Self-hosted
    drivers spawn with ``--sessions 1``.)
    """
    out = announce if announce is not None else sys.stdout
    lock = threading.Lock()
    state = {"served": 0}
    gate = (threading.BoundedSemaphore(max_conns)
            if max_conns is not None else None)
    workers: list[threading.Thread] = []
    with _socket.create_server((host, port)) as srv:
        print(f"LISTENING {srv.getsockname()[1]}", file=out, flush=True)
        accepted = 0
        while sessions is None or accepted < sessions:
            if gate is not None:
                gate.acquire()
            try:
                conn, peer = srv.accept()
            except BaseException:
                if gate is not None:
                    gate.release()
                raise
            accepted += 1
            t = threading.Thread(
                target=_serve_connection, args=(conn, peer, lock, state, gate),
                name=f"hw-session-{accepted}", daemon=True)
            t.start()
            workers.append(t)
            workers = [w for w in workers if w.is_alive()]
    for t in workers:                   # bounded lifetime: drain, then exit
        t.join()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="repro.hw twin server (op-stream driver protocol v4, "
                    "v3 fallback)")
    ap.add_argument("--socket", metavar="HOST:PORT", default=None,
                    help="serve over TCP instead of stdin/stdout "
                         "(PORT=0 picks an ephemeral port)")
    ap.add_argument("--max-conns", type=int, default=None,
                    help="serve at most N socket sessions CONCURRENTLY "
                         "(default: unbounded)")
    ap.add_argument("--sessions", type=int, default=None,
                    help="exit after N socket sessions total (default: "
                         "serve forever)")
    args = ap.parse_args(argv)
    if args.socket is not None:
        host, _, port = args.socket.rpartition(":")
        serve_socket(host or "127.0.0.1", int(port),
                     max_conns=args.max_conns, sessions=args.sessions)
        return 0
    # stdout is the wire: anything else (jax chatter) must go to stderr
    serve(sys.stdin.buffer, sys.stdout.buffer)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
