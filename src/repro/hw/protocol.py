"""Op-stream wire protocol for out-of-process drivers (v4: binary framing).

The normative spec — byte-level frame layout, the handshake/fallback
matrix, op-whitelist semantics, and error-frame behavior — lives in
``docs/wire-protocol.md``; this docstring summarizes the codec this
module implements.

Request/response frames over any *byte* stream (the subprocess transport
uses stdin/stdout pipes, the socket transport a TCP connection — same
framing)::

    → {"id": 7, "op": "forward", "kw": {"x": {"__nd__": ...}, ...}}
    ← {"id": 7, "ok": true, "result": {"y": {"__nd__": ...}}}
    ← {"id": 8, "ok": false, "error": "..."}

Two frame encodings share the stream, distinguished by the first byte:

* **JSON lines** (v3 and earlier, and every ``init`` frame) — one
  newline-terminated UTF-8 JSON document.  Arrays travel as base64 of
  their raw bytes plus dtype/shape.
* **Binary frames** (v4) — a length-prefixed frame whose array payloads
  are raw little-endian bytes, zero base64::

      ┌──────────┬───────────┬──────────────┬───────────────┬──────────┐
      │ MAGIC ×4 │ json_len  │ payload_len  │ JSON metadata │ payload  │
      │ 00 52 42 │ u32 LE    │ u32 LE       │ (json_len B)  │ raw LE   │
      │ 34       │           │              │               │ arrays   │
      └──────────┴───────────┴──────────────┴───────────────┴──────────┘

  The JSON section is the same frame dict, with each array node
  replaced by ``{"__nd__": [offset, nbytes], "dtype": ..., "shape":
  ...}`` referencing a slice of the payload section.  The leading
  ``0x00`` magic byte can never begin a JSON text line, so a receiver
  dispatches on one byte — :func:`recv` accepts either encoding on any
  stream, which is what makes the handshake fallback trivial.

Both encodings carry the identical raw array bytes (base64 is just a
transfer coat), so results are **bit-identical across encodings** — the
conformance suite relies on the twin and stream transports returning
identical results for identical seeds, in either framing.  Configs
(``NoiseModel``, ``DriftConfig``, ``ZOConfig``) travel as plain field
dicts.

Framing limits: a frame longer than ``MAX_FRAME_BYTES`` is rejected
(:class:`ProtocolError`) *without* buffering the whole frame — a
misbehaving peer cannot balloon the server's memory — and a line that is
not valid JSON is likewise a hard :class:`ProtocolError` (the stream is
assumed desynced; the connection terminates rather than guessing).
Limits are enforced in **encoded bytes** on both paths (a v3 frame full
of multi-byte UTF-8 used to be measured in code points, undershooting
the byte ceiling the docstring promises).

The ``batch`` frame (v3)
------------------------
One request can carry an ordered op list executed server-side in one
round-trip::

    → {"id": 9, "op": "batch",
       "kw": {"ops": [{"op": "advance", "kw": {"dt": 1.0}},
                      {"op": "forward", "kw": {"x": ...}}]}}
    ← {"id": 9, "ok": true, "result": [null, {"y": ...}]}

Ops execute strictly in list order against the same device, exactly as
if issued as individual frames — results are bit-identical to the
sequential encoding, and every op inside the batch is metered
individually (one batch ≠ one PTC call).  A failing op aborts the rest
of the list; ops before it have already been applied (the same state
the sequential encoding would have left), and the error names the
failing index.  ``batch`` / ``init`` / ``shutdown`` cannot nest inside
a batch.

A run of consecutive ``forward`` ops with equal probe shape, category,
and ``block_range`` may come back as ONE span entry
``{"coalesced": n, "y": <(n, ...) nd>}`` in place of its ``n`` per-op
results — the server executed them as one vectorized device call and
stacked the (bit-identical) outputs so the span pays one codec pass
instead of ``n``; clients split the leading axis back into per-op
results.

Versioning: the client sends ``{"v": ...}`` inside the ``init`` op's
kwargs — always as a JSON line, so any server can parse it — and the
server echoes the *negotiated* version in the init result.

* v1 — original surface (PR 2): whole-chip ops only.
* v2 — multi-tenant surface: ``block_range`` on the stateful ops;
  version handshake added.
* v3 — op-stream data plane: the ``batch`` frame (client-side write
  pipelining rides on it), frame-size limits, and the socket transport.
* v4 — binary framing (above) + concurrent server sessions + the async
  client.  A v4 server still speaks v3 (``SUPPORTED_VERSIONS``): a v3
  client negotiates v3 in the init handshake and the session stays on
  JSON lines.  A v4 client refused by a v3-only server ("protocol
  mismatch" init error) retries the init with ``v=3`` on the same
  connection — results are bit-identical either way, only the codec
  cost differs.  v1/v2 peers are still hard-rejected on both sides (a
  stale peer would misinterpret batched or tenant-scoped ops).
"""

from __future__ import annotations

import base64
import json
import struct
from typing import Any, BinaryIO

import numpy as np

__all__ = ["encode", "decode", "send", "recv", "ProtocolError",
           "PROTOCOL_VERSION", "SUPPORTED_VERSIONS", "MAX_FRAME_BYTES"]

PROTOCOL_VERSION = 4

# versions a v4 server will negotiate down to in the init handshake
SUPPORTED_VERSIONS = (3, 4)

# Generous ceiling: the largest legitimate frames carry whole-chip phase
# banks / block targets.  64 MiB of frame ≈ a 16M-parameter write — far
# beyond any single-chip op here.
MAX_FRAME_BYTES = 64 * 1024 * 1024

_ND = "__nd__"

# binary frame header: magic (0x00 can never start a JSON text line),
# then u32 LE json-section length + u32 LE payload-section length
_MAGIC = b"\x00RB4"
_HEADER = struct.Struct("<II")


class ProtocolError(RuntimeError):
    """Framing / transport failure on the driver stream."""


def encode(obj: Any, binary: bool = False) -> Any:
    """Recursively wire-encode a python/jax value tree.

    With ``binary=False`` (the JSON-line codec) arrays become base64
    ``__nd__`` nodes.  With ``binary=True`` the ``__nd__`` value is the
    array's raw little-endian bytes — :func:`send` hoists those into the
    frame's payload section, zero base64.  :func:`decode` accepts both
    node forms, so a value encoded for one framing still decodes if it
    ends up inside the other (e.g. a pipelined op queued before the
    handshake settled the session codec).
    """
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, dict):
        return {k: encode(v, binary) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [encode(v, binary) for v in obj]
    arr = np.asarray(obj)
    if arr.dtype.byteorder == ">":       # wire order is little-endian
        arr = arr.astype(arr.dtype.newbyteorder("<"))
    raw = arr.tobytes()
    return {_ND: raw if binary else base64.b64encode(raw).decode("ascii"),
            "dtype": str(arr.dtype), "shape": list(arr.shape)}


def decode(obj: Any) -> Any:
    """Inverse of :func:`encode` (arrays come back as numpy).

    ``__nd__`` payloads may be base64 strings (JSON-line frames) or raw
    bytes / memoryviews (binary frames, resolved by :func:`recv`).
    """
    if isinstance(obj, dict):
        if _ND in obj:
            raw = obj[_ND]
            if isinstance(raw, str):
                raw = base64.b64decode(raw)
            return np.frombuffer(raw, dtype=np.dtype(obj["dtype"])).reshape(
                obj["shape"]).copy()
        return {k: decode(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [decode(v) for v in obj]
    return obj


def _hoist_payload(obj: Any, chunks: list, sizes: list) -> Any:
    """Rebuild ``obj`` with raw-bytes ``__nd__`` nodes replaced by
    ``[offset, nbytes]`` references into the payload section (the
    chunks are concatenated in reference order).  The input tree is
    never mutated — a pipelined frame may be re-encoded after an
    oversized split."""
    if isinstance(obj, dict):
        raw = obj.get(_ND)
        if isinstance(raw, (bytes, bytearray, memoryview)):
            off = sizes[0]
            chunks.append(raw)
            sizes[0] = off + len(raw)
            node = dict(obj)
            node[_ND] = [off, len(raw)]
            return node
        return {k: _hoist_payload(v, chunks, sizes) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_hoist_payload(v, chunks, sizes) for v in obj]
    return obj


def _resolve_payload(obj: Any, payload: memoryview) -> Any:
    """Inverse of :func:`_hoist_payload`: ``[offset, nbytes]`` node
    references become (zero-copy) memoryview slices of the payload."""
    if isinstance(obj, dict):
        ref = obj.get(_ND)
        if isinstance(ref, list) and len(ref) == 2:
            off, n = int(ref[0]), int(ref[1])
            if off < 0 or n < 0 or off + n > len(payload):
                raise ProtocolError(
                    f"binary frame payload reference [{off}, {n}] out of "
                    f"bounds for a {len(payload)}-byte payload section")
            node = dict(obj)
            node[_ND] = payload[off:off + n]
            return node
        return {k: _resolve_payload(v, payload) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_resolve_payload(v, payload) for v in obj]
    return obj


def send(fp: BinaryIO, msg: dict, binary: bool = False) -> None:
    """Write one frame.  Size limits are enforced in encoded bytes and
    checked BEFORE anything is written — an oversized frame leaves the
    stream exactly as it was (callers rely on this to split op lists
    and to keep a session alive after refusing a too-large result)."""
    if binary:
        chunks: list = []
        sizes = [0]
        meta = _hoist_payload(msg, chunks, sizes)
        head = json.dumps(meta, separators=(",", ":")).encode("utf-8")
        total = len(_MAGIC) + _HEADER.size + len(head) + sizes[0]
        if total > MAX_FRAME_BYTES:
            raise ProtocolError(
                f"refusing to send oversized frame ({total} bytes > "
                f"{MAX_FRAME_BYTES})")
        fp.write(_MAGIC)
        fp.write(_HEADER.pack(len(head), sizes[0]))
        fp.write(head)
        for chunk in chunks:
            fp.write(chunk)
    else:
        data = (json.dumps(msg, separators=(",", ":")) + "\n").encode("utf-8")
        if len(data) > MAX_FRAME_BYTES:
            raise ProtocolError(
                f"refusing to send oversized frame ({len(data)} bytes > "
                f"{MAX_FRAME_BYTES})")
        fp.write(data)
    fp.flush()


def _read_exact(fp: BinaryIO, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = fp.read(n - len(buf))
        if not chunk:
            raise ProtocolError(
                "driver stream closed mid-frame (peer exited?)")
        buf.extend(chunk)
    return bytes(buf)


def recv(fp: BinaryIO, max_bytes: int = MAX_FRAME_BYTES) -> dict:
    """Read one frame, auto-detecting the encoding from its first byte
    (``0x00`` → binary, anything else → JSON line).  Bounded: neither
    path buffers more than ``max_bytes`` before rejecting."""
    first = fp.read(1)
    if not first:
        raise ProtocolError("driver stream closed (peer exited?)")
    if first == _MAGIC[:1]:
        magic = first + _read_exact(fp, len(_MAGIC) - 1)
        if magic != _MAGIC:
            raise ProtocolError(
                f"malformed binary frame: bad magic {magic!r}")
        json_len, payload_len = _HEADER.unpack(
            _read_exact(fp, _HEADER.size))
        total = len(_MAGIC) + _HEADER.size + json_len + payload_len
        if total > max_bytes:
            raise ProtocolError(
                f"oversized frame rejected (> {max_bytes} bytes)")
        head = _read_exact(fp, json_len)
        payload = memoryview(_read_exact(fp, payload_len))
        try:
            meta = json.loads(head)
        except json.JSONDecodeError as e:
            raise ProtocolError(
                f"malformed binary frame metadata: {head[:200]!r}") from e
        if not isinstance(meta, dict):
            raise ProtocolError(
                f"malformed frame: expected a dict, got {type(meta).__name__}")
        return _resolve_payload(meta, payload)
    # JSON line: bounded readline — a peer streaming an endless line
    # cannot make us buffer more than the frame ceiling (counted in
    # BYTES: multi-byte UTF-8 used to slip past a code-point count)
    line = first + fp.readline(max_bytes)
    if len(line) > max_bytes or (len(line) == max_bytes
                                 and not line.endswith(b"\n")):
        raise ProtocolError(
            f"oversized frame rejected (> {max_bytes} bytes)")
    try:
        msg = json.loads(line)
    except json.JSONDecodeError as e:
        raise ProtocolError(f"malformed frame: {line[:200]!r}") from e
    if not isinstance(msg, dict):
        # normalize here so both framings reject non-dict frames the
        # same way (serve() turns this into an error frame + live
        # session rather than a dropped connection)
        return {"__non_dict__": msg}
    return msg
