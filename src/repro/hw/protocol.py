"""JSON-over-pipe wire protocol for out-of-process drivers.

Newline-delimited JSON request/response frames::

    → {"id": 7, "op": "forward", "kw": {"x": {"__nd__": ...}, ...}}
    ← {"id": 7, "ok": true, "result": {"y": {"__nd__": ...}}}
    ← {"id": 8, "ok": false, "error": "..."}

Arrays travel as base64 of their raw bytes plus dtype/shape, so float32
round-trips bit-exactly — the conformance suite relies on the twin and
subprocess transports returning identical results for identical seeds.
Configs (``NoiseModel``, ``DriftConfig``, ``ZOConfig``) travel as plain
field dicts.

Versioning: the client sends ``{"v": PROTOCOL_VERSION}`` inside the
``init`` op's kwargs and the server echoes its own version in the init
result; a mismatch is a hard error on both sides (no silent fallback —
a stale server would misinterpret tenant-scoped ops).

* v1 — original surface (PR 2): whole-chip ops only.
* v2 — multi-tenant surface: ``block_range`` on ``write_phases`` /
  ``write_sigma`` / ``write_signs`` / ``forward`` / ``forward_layer``
  (+ ``out_dim``) / ``readback_bases`` / ``zo_refine`` and on
  ``unsafe/true_mapping_distance``; version handshake added.
"""

from __future__ import annotations

import base64
import json
from typing import Any, IO

import numpy as np

__all__ = ["encode", "decode", "send", "recv", "ProtocolError",
           "PROTOCOL_VERSION"]

PROTOCOL_VERSION = 2

_ND = "__nd__"


class ProtocolError(RuntimeError):
    """Framing / transport failure on the driver pipe."""


def encode(obj: Any) -> Any:
    """Recursively JSON-encode a python/jax value tree."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, dict):
        return {k: encode(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [encode(v) for v in obj]
    arr = np.asarray(obj)
    return {_ND: base64.b64encode(arr.tobytes()).decode("ascii"),
            "dtype": str(arr.dtype), "shape": list(arr.shape)}


def decode(obj: Any) -> Any:
    """Inverse of :func:`encode` (arrays come back as numpy)."""
    if isinstance(obj, dict):
        if _ND in obj:
            raw = base64.b64decode(obj[_ND])
            return np.frombuffer(raw, dtype=np.dtype(obj["dtype"])).reshape(
                obj["shape"]).copy()
        return {k: decode(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [decode(v) for v in obj]
    return obj


def send(fp: IO[str], msg: dict) -> None:
    fp.write(json.dumps(msg, separators=(",", ":")) + "\n")
    fp.flush()


def recv(fp: IO[str]) -> dict:
    line = fp.readline()
    if not line:
        raise ProtocolError("driver pipe closed (peer exited?)")
    try:
        return json.loads(line)
    except json.JSONDecodeError as e:
        raise ProtocolError(f"malformed frame: {line[:200]!r}") from e
