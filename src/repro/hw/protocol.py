"""JSON op-stream wire protocol for out-of-process drivers.

Newline-delimited JSON request/response frames, over any byte stream
(the subprocess transport uses stdin/stdout pipes, the socket transport
a TCP connection — same framing)::

    → {"id": 7, "op": "forward", "kw": {"x": {"__nd__": ...}, ...}}
    ← {"id": 7, "ok": true, "result": {"y": {"__nd__": ...}}}
    ← {"id": 8, "ok": false, "error": "..."}

Arrays travel as base64 of their raw bytes plus dtype/shape, so float32
round-trips bit-exactly — the conformance suite relies on the twin and
stream transports returning identical results for identical seeds.
Configs (``NoiseModel``, ``DriftConfig``, ``ZOConfig``) travel as plain
field dicts.

Framing limits: a frame longer than ``MAX_FRAME_BYTES`` is rejected
(:class:`ProtocolError`) *without* buffering the whole line — a
misbehaving peer cannot balloon the server's memory — and a line that is
not valid JSON is likewise a hard :class:`ProtocolError` (the stream is
assumed desynced; the connection terminates rather than guessing).

The ``batch`` frame (v3)
------------------------
One request can carry an ordered op list executed server-side in one
round-trip::

    → {"id": 9, "op": "batch",
       "kw": {"ops": [{"op": "advance", "kw": {"dt": 1.0}},
                      {"op": "forward", "kw": {"x": ...}}]}}
    ← {"id": 9, "ok": true, "result": [null, {"y": ...}]}

Ops execute strictly in list order against the same device, exactly as
if issued as individual frames — results are bit-identical to the
sequential encoding, and every op inside the batch is metered
individually (one batch ≠ one PTC call).  A failing op aborts the rest
of the list; ops before it have already been applied (the same state
the sequential encoding would have left), and the error names the
failing index.  ``batch`` / ``init`` / ``shutdown`` cannot nest inside
a batch.

A run of consecutive ``forward`` ops with equal probe shape, category,
and ``block_range`` may come back as ONE span entry
``{"coalesced": n, "y": <(n, ...) nd>}`` in place of its ``n`` per-op
results — the server executed them as one vectorized device call and
stacked the (bit-identical) outputs so the span pays one codec pass
instead of ``n``; clients split the leading axis back into per-op
results.

Versioning: the client sends ``{"v": PROTOCOL_VERSION}`` inside the
``init`` op's kwargs and the server echoes its own version in the init
result; a mismatch is a hard error on both sides (no silent fallback —
a stale peer would misinterpret batched or tenant-scoped ops).

* v1 — original surface (PR 2): whole-chip ops only.
* v2 — multi-tenant surface: ``block_range`` on ``write_phases`` /
  ``write_sigma`` / ``write_signs`` / ``forward`` / ``forward_layer``
  (+ ``out_dim``) / ``readback_bases`` / ``zo_refine`` and on
  ``unsafe/true_mapping_distance``; version handshake added.
* v3 — op-stream data plane: the ``batch`` frame (client-side write
  pipelining rides on it), frame-size limits, and the socket transport
  (same framing over TCP).  A v2 peer would treat a ``batch`` frame as
  an unknown op mid-session, so the handshake hard-rejects it.
"""

from __future__ import annotations

import base64
import json
from typing import Any, IO

import numpy as np

__all__ = ["encode", "decode", "send", "recv", "ProtocolError",
           "PROTOCOL_VERSION", "MAX_FRAME_BYTES"]

PROTOCOL_VERSION = 3

# Generous ceiling: the largest legitimate frames carry whole-chip phase
# banks / block targets (base64 inflates raw float32 by 4/3).  64 MiB of
# frame ≈ a 12M-parameter write — far beyond any single-chip op here.
MAX_FRAME_BYTES = 64 * 1024 * 1024

_ND = "__nd__"


class ProtocolError(RuntimeError):
    """Framing / transport failure on the driver stream."""


def encode(obj: Any) -> Any:
    """Recursively JSON-encode a python/jax value tree."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, dict):
        return {k: encode(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [encode(v) for v in obj]
    arr = np.asarray(obj)
    return {_ND: base64.b64encode(arr.tobytes()).decode("ascii"),
            "dtype": str(arr.dtype), "shape": list(arr.shape)}


def decode(obj: Any) -> Any:
    """Inverse of :func:`encode` (arrays come back as numpy)."""
    if isinstance(obj, dict):
        if _ND in obj:
            raw = base64.b64decode(obj[_ND])
            return np.frombuffer(raw, dtype=np.dtype(obj["dtype"])).reshape(
                obj["shape"]).copy()
        return {k: decode(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [decode(v) for v in obj]
    return obj


def send(fp: IO[str], msg: dict) -> None:
    line = json.dumps(msg, separators=(",", ":"))
    if len(line) + 1 > MAX_FRAME_BYTES:
        raise ProtocolError(
            f"refusing to send oversized frame ({len(line) + 1} bytes > "
            f"{MAX_FRAME_BYTES})")
    fp.write(line + "\n")
    fp.flush()


def recv(fp: IO[str], max_bytes: int = MAX_FRAME_BYTES) -> dict:
    # bounded readline: a peer streaming an endless line cannot make us
    # buffer more than the frame ceiling before we reject it
    line = fp.readline(max_bytes + 1)
    if not line:
        raise ProtocolError("driver stream closed (peer exited?)")
    if len(line) > max_bytes or (len(line) == max_bytes
                                 and not line.endswith("\n")):
        raise ProtocolError(
            f"oversized frame rejected (> {max_bytes} bytes)")
    try:
        return json.loads(line)
    except json.JSONDecodeError as e:
        raise ProtocolError(f"malformed frame: {line[:200]!r}") from e
