"""On-controller in-situ search jobs (device-side code).

L2ight's whole point is that the ZO searches are executed *on chip*: a
loss measurement is a physical probe, so the optimizer must be
co-located with the device — shipping per-probe round trips over a
control network (400+ per block per job) would defeat in-situ operation.
These functions are therefore *device-side* implementations shared by
every driver transport:

* :class:`~repro.hw.twin.TwinDriver` calls them directly (in-process);
* the out-of-process twin server (``repro.hw.server``) calls the same
  functions against its local device, so :class:`SubprocessDriver`
  returns bit-identical results for the same seeds.

Control-plane code never imports this module — it requests jobs through
``driver.zo_refine`` / ``driver.run_ic`` and receives only the
observability-legal outputs (commanded phases, basis readbacks, loss
traces).

``phase_refine`` is the warm/alternate ZCD both PM's stage 2 and the
closed-loop recalibrator use; ``ic_search`` is IC's multi-Σ_cal
surrogate search (§3.2, Eq. 2).  All stages run vmapped across the
chip's blocks (independent physical circuits), mirroring the paper's
batched-sub-task scalability trick.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import unitary as un
from ..core.noise import NoiseModel
from ..optim.zo import ZOConfig, ZOResult, zo_minimize
from .device import DeviceRealization, realized_unitaries

__all__ = ["phase_refine", "ic_search"]


def _block_distance(w_hat: jax.Array, w: jax.Array) -> jax.Array:
    """Normalized ‖W−W̃‖²/‖W‖² — the electronic comparison the on-chip
    controller evaluates per probe (same metric as mapping.matrix_distance)."""
    num = jnp.sum((w_hat - w) ** 2, axis=(-2, -1))
    den = jnp.sum(w ** 2, axis=(-2, -1)) + 1e-12
    return num / den


def phase_refine(spec: un.MeshSpec, model: NoiseModel,
                 dev: DeviceRealization, phi0: jax.Array, sigma: jax.Array,
                 w_blocks: jax.Array, key: jax.Array, cfg: ZOConfig,
                 method: str = "zcd") -> ZOResult:
    """Alternate ZCD on ``phi = [Φ^U | Φ^V]`` against per-block targets,
    warm-started from ``phi0`` (B, 2T); vmapped over blocks."""
    t = spec.n_rot
    b = phi0.shape[0]

    def block_err(ph, dev_b, w_b, s_b):
        u, v = realized_unitaries(spec, ph[:t], ph[t:], dev_b, model)
        return _block_distance((u * s_b) @ v, w_b)

    def solve_one(phi_b, key_b, dev_b, w_b, s_b):
        return zo_minimize(lambda ph: block_err(ph, dev_b, w_b, s_b),
                           phi_b, key_b, cfg, method=method, alt_split=t)

    keys = jax.random.split(key, b)
    return jax.jit(jax.vmap(solve_one))(phi0, keys, dev, w_blocks, sigma)


def ic_search(spec: un.MeshSpec, model: NoiseModel, dev: DeviceRealization,
              key: jax.Array, cfg: ZOConfig, sigs: jax.Array,
              method: str = "zcd", restarts: int = 4
              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Identity Calibration's surrogate search (Eq. 2).

    One physical loss measurement = probing the PTC with the k unit
    vectors per Σ_cal setting (coherent I/O) and comparing against
    Σ_cal.  The search uses ``restarts`` cyclic step-size restarts
    (δ₀ halves each cycle), which escapes the surrogate's flat
    directions.  Returns ``(phi, final_loss, history)``.
    """
    t = spec.n_rot
    k = spec.k
    n_blocks = dev.d_u.shape[0]
    eye = jnp.eye(k)

    def loss_fn(phi, dev_b):
        phi_u, phi_v = phi[:t], phi[t:]
        u, v = realized_unitaries(spec, phi_u, phi_v, dev_b, model)
        # observable surrogate: intensity distance (|·|, phase-insensitive)
        l = 0.0
        for i in range(sigs.shape[0]):
            m = ((u * sigs[i]) @ v) / sigs[i]   # U Σ V* Σ⁻¹, Σ⁻¹ electronic
            l = l + jnp.mean((jnp.abs(m) - eye) ** 2)
        return l / sigs.shape[0]

    x = jnp.zeros((n_blocks, 2 * t))
    histories = []
    res = None
    for r in range(restarts):
        keys = jax.random.split(jax.random.fold_in(key, r), n_blocks)
        cfg_r = cfg._replace(delta0=cfg.delta0 / (2.0 ** r))

        def solve_one(x0_b, key_b, dev_b):
            return zo_minimize(lambda p: loss_fn(p, dev_b), x0_b, key_b,
                               cfg_r, method=method)

        res = jax.jit(jax.vmap(solve_one))(x, keys, dev)
        x = res.x
        histories.append(res.history)
    return x, res.f, jnp.concatenate(histories, axis=-1)
