"""On-controller in-situ search jobs (device-side code).

L2ight's whole point is that the ZO searches are executed *on chip*: a
loss measurement is a physical probe, so the optimizer must be
co-located with the device — shipping per-probe round trips over a
control network (400+ per block per job) would defeat in-situ operation
(the wire protocol's v3 ``batch`` frame amortizes *op*-level round
trips; probe-level ones never leave the controller at all).  These
functions are therefore *device-side* implementations shared by every
driver transport:

* :class:`~repro.hw.twin.TwinDriver` calls them directly (in-process);
* the out-of-process twin server (``repro.hw.server``) calls the same
  functions against its local device, so the stream transports
  (:class:`SubprocessDriver`, :class:`SocketDriver`) return
  bit-identical results for the same seeds.

Control-plane code never imports this module — it requests jobs through
``driver.zo_refine`` / ``driver.run_ic`` and receives only the
observability-legal outputs (commanded phases, basis readbacks, loss
traces).

``phase_refine`` is the warm/alternate ZCD both PM's stage 2 and the
closed-loop recalibrator use; ``ic_search`` is IC's multi-Σ_cal
surrogate search (§3.2, Eq. 2).  All stages run vmapped across the
chip's blocks (independent physical circuits), mirroring the paper's
batched-sub-task scalability trick.

Compiled-twin fast path
-----------------------
The whole per-block search is a single ``lax.scan`` (``optim.zo``), and
the jitted+vmapped solver for each (mesh, noise model, budget, method)
signature is **cached at module level** — the closed loop re-runs
``zo_refine`` with the same signature on every recalibration, and
re-tracing the scan each time used to dominate the job's wall clock
(~1.2 s of trace+compile per call at the benchmark geometry, vs
milliseconds of execution).  IC's cyclic restarts likewise hit one
cached compilation per (budget, δ₀, Σ_cal schedule) signature.  The
schedule constants are *baked into the traces* (not passed traced):
constant folding keeps the float rounding — and hence the ZCD's
probe-comparison branches — bit-identical to the historical searches.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core import unitary as un
from ..core.noise import NoiseModel
from ..optim.zo import ZOConfig, ZOResult, zo_minimize
from .device import DeviceRealization, realized_unitaries

__all__ = ["phase_refine", "ic_search"]


def _block_distance(w_hat: jax.Array, w: jax.Array) -> jax.Array:
    """Normalized ‖W−W̃‖²/‖W‖² — the electronic comparison the on-chip
    controller evaluates per probe (same metric as mapping.matrix_distance)."""
    num = jnp.sum((w_hat - w) ** 2, axis=(-2, -1))
    den = jnp.sum(w ** 2, axis=(-2, -1)) + 1e-12
    return num / den


@functools.lru_cache(maxsize=128)
def _phase_refine_fn(k: int, kind: str, model: NoiseModel, cfg: ZOConfig,
                     method: str):
    """Compiled vmapped alternate-ZCD solver, cached per job signature.

    The cache key is everything that shapes the trace: mesh geometry,
    noise model (frozen dataclass, hashable), the full ZO budget (scan
    length / decay schedule are baked into the compiled loop), and the
    method.  Distinct autotuned budgets compile once each and are then
    shared by every driver and every recalibration job fleet-wide.
    """
    spec = un.mesh_spec(k, kind)
    t = spec.n_rot

    def block_err(ph, dev_b, w_b, s_b):
        u, v = realized_unitaries(spec, ph[:t], ph[t:], dev_b, model)
        return _block_distance((u * s_b) @ v, w_b)

    def solve_one(phi_b, key_b, dev_b, w_b, s_b):
        return zo_minimize(lambda ph: block_err(ph, dev_b, w_b, s_b),
                           phi_b, key_b, cfg, method=method, alt_split=t)

    return jax.jit(jax.vmap(solve_one))


def phase_refine(spec: un.MeshSpec, model: NoiseModel,
                 dev: DeviceRealization, phi0: jax.Array, sigma: jax.Array,
                 w_blocks: jax.Array, key: jax.Array, cfg: ZOConfig,
                 method: str = "zcd") -> ZOResult:
    """Alternate ZCD on ``phi = [Φ^U | Φ^V]`` against per-block targets,
    warm-started from ``phi0`` (B, 2T); vmapped over blocks, one cached
    compilation per job signature."""
    keys = jax.random.split(key, phi0.shape[0])
    solver = _phase_refine_fn(spec.k, spec.kind, model, cfg, method)
    return solver(phi0, keys, dev, w_blocks, sigma)


@functools.lru_cache(maxsize=256)
def _ic_solver_fn(k: int, kind: str, model: NoiseModel, cfg: ZOConfig,
                  method: str, sigs_wire: bytes, n_sigma: int):
    """Compiled vmapped IC surrogate search, cached per signature.

    The Σ_cal probe schedule and the restart's δ₀ are baked into the
    trace as compile-time constants — exactly the pre-cache semantics
    (folding them keeps the surrogate's float rounding, and hence the
    ZCD's probe-comparison branches, bit-identical to the historical
    search); a (cfg, schedule) signature therefore compiles once per
    restart and is shared by every subsequent IC job fleet-wide.
    """
    spec = un.mesh_spec(k, kind)
    t = spec.n_rot
    eye = jnp.eye(k)
    sigs = jnp.asarray(
        np.frombuffer(sigs_wire, dtype=np.float32).reshape(n_sigma, k))

    def loss_fn(phi, dev_b):
        phi_u, phi_v = phi[:t], phi[t:]
        u, v = realized_unitaries(spec, phi_u, phi_v, dev_b, model)
        # observable surrogate: intensity distance (|·|, phase-insensitive)
        l = 0.0
        for i in range(n_sigma):
            m = ((u * sigs[i]) @ v) / sigs[i]   # U Σ V* Σ⁻¹, Σ⁻¹ electronic
            l = l + jnp.mean((jnp.abs(m) - eye) ** 2)
        return l / n_sigma

    def solve_one(x0_b, key_b, dev_b):
        return zo_minimize(lambda p: loss_fn(p, dev_b), x0_b, key_b, cfg,
                           method=method)

    return jax.jit(jax.vmap(solve_one))


def ic_search(spec: un.MeshSpec, model: NoiseModel, dev: DeviceRealization,
              key: jax.Array, cfg: ZOConfig, sigs: jax.Array,
              method: str = "zcd", restarts: int = 4
              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Identity Calibration's surrogate search (Eq. 2).

    One physical loss measurement = probing the PTC with the k unit
    vectors per Σ_cal setting (coherent I/O) and comparing against
    Σ_cal.  The search uses ``restarts`` cyclic step-size restarts
    (δ₀ halves each cycle), which escapes the surrogate's flat
    directions.  Returns ``(phi, final_loss, history)``.
    """
    t = spec.n_rot
    n_blocks = dev.d_u.shape[0]
    sigs_wire = np.asarray(sigs, np.float32).tobytes()

    x = jnp.zeros((n_blocks, 2 * t))
    histories = []
    res = None
    for r in range(restarts):
        keys = jax.random.split(jax.random.fold_in(key, r), n_blocks)
        cfg_r = cfg._replace(delta0=cfg.delta0 / (2.0 ** r))
        solver = _ic_solver_fn(spec.k, spec.kind, model, cfg_r, method,
                               sigs_wire, int(sigs.shape[0]))
        res = solver(x, keys, dev)
        x = res.x
        histories.append(res.history)
    return x, res.f, jnp.concatenate(histories, axis=-1)
