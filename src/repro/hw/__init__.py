"""Hardware control plane (DESIGN): one observability boundary.

The paper's constraint (§3.2) — on chip, only the end-to-end ``UΣV*``
response is observable — is enforced here as an API boundary::

    control plane (generic over the ABC)          device side (twin physics)
    ──────────────────────────────────            ──────────────────────────
    core/calibration.py   IC                      hw/device.py   realization
    core/mapping.py       PM + OSP        ───▶    hw/drift.py    OU walk
    runtime/monitor.py    health probes  driver   hw/jobs.py     ZO searches
    runtime/recalibrate.py closed loop    ABC     hw/twin.py     TwinDriver
    runtime/fleet.py      serving/routing ───▶    hw/server.py   remote twin

    hw/driver.py             the ABC + PTC-call accounting
    hw/stream_driver.py      shared op-stream client (pipelining, batch)
    hw/subprocess_driver.py  pipe transport (HIL topology)
    hw/socket_driver.py      TCP transport (remote-host topology)

Three transports ship: :class:`TwinDriver` (in-process, jit-friendly)
and two op-stream clients sharing one :class:`StreamDriver` base —
:class:`SubprocessDriver` (JSON over stdin/stdout pipes to
``repro.hw.server``, the hardware-in-the-loop shape) and
:class:`SocketDriver` (the same framing over TCP, so the device server
can run on another host; swap the server for a real instrument daemon
and the control plane is untouched).  All meter every op that touches
light in Appendix-G PTC calls (:class:`DriverStats`).

All transports are *tenant-addressable* (wire protocol v2 surface):
state writes, probes, and in-situ jobs accept ``block_range=(start,
stop)`` scoping them to one mapped layer's blocks when a chip is time-
multiplexed across several tenants (``repro.runtime.fleet`` keeps the
tenant → block-range registry on top of this).  Protocol v3 adds the
*batched data plane*: ``driver.run_batch`` ships an ordered op list in
one wire frame, and the stream transports pipeline result-less writes
into the next observable op's frame — closing the ~23× probe-throughput
gap the per-op round-trips cost (``benchmarks/driver_overhead.py``).

Twin-only readouts (exact mapping distance, the drifted realization) are
reachable only through ``driver.unsafe_twin()`` — tests and benchmarks
only; ``tests/test_driver.py`` guards the import boundary.
"""

from .driver import (PhotonicDriver, DriverStats, ZORefineResult,  # noqa: F401
                     ICJobResult, TwinUnavailable, probe_cost,
                     readback_cost, resolve_block_range)
from .drift import (DriftConfig, DriftState, init_drift, advance,  # noqa: F401
                    bias_deviation, DEFAULT_DRIFT)
from .protocol import PROTOCOL_VERSION, MAX_FRAME_BYTES  # noqa: F401
from .twin import TwinDriver, TwinHandle, make_twin  # noqa: F401
from .stream_driver import StreamDriver  # noqa: F401
from .subprocess_driver import SubprocessDriver  # noqa: F401
from .socket_driver import SocketDriver  # noqa: F401

__all__ = ["PhotonicDriver", "DriverStats", "ZORefineResult", "ICJobResult",
           "TwinUnavailable", "probe_cost", "readback_cost",
           "resolve_block_range", "PROTOCOL_VERSION", "MAX_FRAME_BYTES",
           "DriftConfig", "DriftState", "init_drift", "advance",
           "bias_deviation", "DEFAULT_DRIFT", "TwinDriver", "TwinHandle",
           "make_twin", "StreamDriver", "SubprocessDriver", "SocketDriver",
           "make_driver"]


def make_driver(transport: str, key, n_blocks: int, k: int, model,
                kind: str = "clements", *, m: int | None = None,
                n: int | None = None, drift=None,
                address: tuple[str, int] | None = None) -> PhotonicDriver:
    """Uniform driver factory: ``transport`` ∈ {"twin", "subprocess",
    "socket"}.  ``address=(host, port)`` points the socket transport at
    a remote ``repro.hw.server --socket`` daemon; without it the socket
    driver self-hosts a loopback server child."""
    if transport == "twin":
        return make_twin(key, n_blocks, k, model, kind, m=m, n=n, drift=drift)
    if transport == "subprocess":
        return SubprocessDriver(key, n_blocks, k, model, kind, m=m, n=n,
                                drift=drift)
    if transport == "socket":
        return SocketDriver(key, n_blocks, k, model, kind, m=m, n=n,
                            drift=drift, address=address)
    raise ValueError(f"unknown driver transport: {transport!r}")
