"""Hardware control plane (DESIGN): one observability boundary.

The paper's constraint (§3.2) — on chip, only the end-to-end ``UΣV*``
response is observable — is enforced here as an API boundary::

    control plane (generic over the ABC)          device side (twin physics)
    ──────────────────────────────────            ──────────────────────────
    core/calibration.py   IC                      hw/device.py   realization
    core/mapping.py       PM + OSP        ───▶    hw/drift.py    OU walk
    runtime/monitor.py    health probes  driver   hw/jobs.py     ZO searches
    runtime/recalibrate.py closed loop    ABC     hw/twin.py     TwinDriver
    runtime/fleet.py      serving/routing ───▶    hw/server.py   remote twin

    hw/driver.py             the ABC + PTC-call accounting
    hw/stream_driver.py      shared op-stream client (pipelining, batch,
                             async reader)
    hw/subprocess_driver.py  pipe transport (HIL topology)
    hw/socket_driver.py      TCP transport (remote-host topology)
    hw/instrument_driver.py  real-instrument skeleton (ABC minus
                             unsafe_twin)

Three transports ship: :class:`TwinDriver` (in-process, jit-friendly)
and two op-stream clients sharing one :class:`StreamDriver` base —
:class:`SubprocessDriver` (framed bytes over stdin/stdout pipes to
``repro.hw.server``, the hardware-in-the-loop shape) and
:class:`SocketDriver` (the same framing over TCP, so the device server
can run on another host; swap the server for a real instrument daemon
and the control plane is untouched — :class:`ReferenceInstrumentDriver`
is the skeleton such a daemon would host).  All meter every op that
touches light in Appendix-G PTC calls (:class:`DriverStats`).

All transports are *tenant-addressable* (wire protocol v2 surface):
state writes, probes, and in-situ jobs accept ``block_range=(start,
stop)`` scoping them to one mapped layer's blocks when a chip is time-
multiplexed across several tenants (``repro.runtime.fleet`` keeps the
tenant → block-range registry on top of this).  Protocol v3 adds the
*batched data plane*: ``driver.run_batch`` ships an ordered op list in
one wire frame, and the stream transports pipeline result-less writes
into the next observable op's frame — closing the ~23× probe-throughput
gap the per-op round-trips cost (``benchmarks/driver_overhead.py``).
Protocol v4 makes the plane concurrent: binary frames (raw little-endian
array payloads, no base64) negotiated at init with a v3 JSON-line
fallback, a thread-per-connection socket server (one twin-farm process
serves a whole fleet), and ``driver.run_batch_async`` — issue the frame
now, collect the future later — which ``repro.runtime.fleet`` uses to
overlap probe sweeps and serve passes across chips.  Every encoding and
scheduling choice is bit-identical by construction; only the wall-clock
changes.

Twin-only readouts (exact mapping distance, the drifted realization) are
reachable only through ``driver.unsafe_twin()`` — tests and benchmarks
only; ``tests/test_driver.py`` guards the import boundary.
"""

from .driver import (PhotonicDriver, DriverStats, ZORefineResult,  # noqa: F401
                     ICJobResult, TwinUnavailable, CompletedBatch,
                     probe_cost, readback_cost, resolve_block_range)
from .drift import (DriftConfig, DriftState, init_drift, advance,  # noqa: F401
                    bias_deviation, DEFAULT_DRIFT)
from .protocol import (PROTOCOL_VERSION, SUPPORTED_VERSIONS,  # noqa: F401
                       MAX_FRAME_BYTES)
from .twin import TwinDriver, TwinHandle, make_twin  # noqa: F401
from .stream_driver import StreamDriver, BatchFuture  # noqa: F401
from .subprocess_driver import SubprocessDriver  # noqa: F401
from .socket_driver import SocketDriver  # noqa: F401
from .instrument_driver import ReferenceInstrumentDriver  # noqa: F401

__all__ = ["PhotonicDriver", "DriverStats", "ZORefineResult", "ICJobResult",
           "TwinUnavailable", "CompletedBatch", "probe_cost",
           "readback_cost", "resolve_block_range", "PROTOCOL_VERSION",
           "SUPPORTED_VERSIONS", "MAX_FRAME_BYTES", "DriftConfig",
           "DriftState", "init_drift", "advance", "bias_deviation",
           "DEFAULT_DRIFT", "TwinDriver", "TwinHandle", "make_twin",
           "StreamDriver", "BatchFuture", "SubprocessDriver",
           "SocketDriver", "ReferenceInstrumentDriver", "make_driver"]


def make_driver(transport: str, key, n_blocks: int, k: int, model,
                kind: str = "clements", *, m: int | None = None,
                n: int | None = None, drift=None,
                address: tuple[str, int] | None = None,
                protocol: int | None = None) -> PhotonicDriver:
    """Uniform driver factory: ``transport`` ∈ {"twin", "subprocess",
    "socket"}.  ``address=(host, port)`` points the socket transport at
    a remote ``repro.hw.server --socket`` daemon; without it the socket
    driver self-hosts a loopback server child.  ``protocol`` pins the
    stream transports to a specific wire version (3 or 4) instead of
    negotiating v4-with-v3-fallback."""
    if transport == "twin":
        return make_twin(key, n_blocks, k, model, kind, m=m, n=n, drift=drift)
    if transport == "subprocess":
        return SubprocessDriver(key, n_blocks, k, model, kind, m=m, n=n,
                                drift=drift, protocol=protocol)
    if transport == "socket":
        return SocketDriver(key, n_blocks, k, model, kind, m=m, n=n,
                            drift=drift, address=address, protocol=protocol)
    raise ValueError(f"unknown driver transport: {transport!r}")
