"""Time-dependent device drift: seeded Ornstein–Uhlenbeck phase walk.

The seed repo treats a chip as a one-shot artifact — ``sample_device``
draws Γ/Φ_b once and the realization is frozen forever.  Real photonic
meshes drift: thermal gradients and aging move the phase biases on a
scale of minutes-to-days, which is the whole motivation for *in-situ*
re-optimization (L2ight §3.2).  This module layers a time axis on top of
``core.noise``'s static :class:`PhaseNoise`:

* the *anchor* is the manufacturing realization (what ``sample_device``
  drew) — drift is mean-reverting toward it (thermal fluctuation) plus
  an optional deterministic ramp (aging);
* :func:`advance` performs one Euler–Maruyama step of the OU SDE

      dφ_b = θ (φ_anchor + a·t − φ_b) dt + σ_φ √dt · dW

  on the phase biases of both meshes (and, optionally, a slower OU walk
  on the multiplicative Γ factors);
* everything is a pure jittable function of ``(state, dt, key)`` —
  drift is exactly reproducible under a fixed seed schedule, which the
  runtime tests rely on.

Only ``Φ_b`` and ``Γ`` move; the manufacturing sign diagonals ``d_u`` /
``d_v`` are topological and fixed for the life of the device.

Like :mod:`repro.hw.device`, this is twin-side physics: a real chip
drifts by itself, so control-plane code only ever sees drift through
``driver.advance(dt)`` (plus probe estimates of its effect).  Only the
:class:`DriftConfig` policy knobs are control-plane-visible.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.noise import PhaseNoise
from .device import DeviceRealization

__all__ = ["DriftConfig", "DriftState", "init_drift", "advance",
           "bias_deviation", "DEFAULT_DRIFT"]


class DriftConfig(NamedTuple):
    """OU drift parameters (units: radians and virtual ticks)."""

    sigma_phase: float = 0.004   # diffusion on the phase biases, rad/√tick
    theta: float = 0.01          # mean reversion rate toward the anchor
    sigma_gamma: float = 0.0     # diffusion on Γ (slow; off by default)
    aging: float = 0.0           # deterministic anchor ramp, rad/tick


DEFAULT_DRIFT = DriftConfig()


class DriftState(NamedTuple):
    """A :class:`DeviceRealization` extended with a time axis.

    ``anchor`` is the manufacturing realization the OU process reverts
    to; ``dev`` is the current (drifted) realization that the simulator
    should feed to ``realized_unitaries`` / ``apply_phase_noise``.
    """

    anchor: DeviceRealization
    dev: DeviceRealization
    t: jax.Array                 # scalar virtual time (ticks)


def init_drift(dev: DeviceRealization) -> DriftState:
    """Start the clock at t=0 with the freshly sampled realization."""
    return DriftState(anchor=dev, dev=dev, t=jnp.zeros((), jnp.float32))


def _ou_step(key, x, x_anchor, theta, sigma, dt):
    eps = jax.random.normal(key, x.shape)
    return x + theta * (x_anchor - x) * dt + sigma * jnp.sqrt(dt) * eps


@functools.partial(jax.jit, static_argnames=())
def _advance(state: DriftState, dt: jax.Array, key: jax.Array,
             cfg: DriftConfig) -> DriftState:
    kbu, kbv, kgu, kgv = jax.random.split(key, 4)
    anchor, dev = state.anchor, state.dev
    ramp = cfg.aging * state.t

    bias_u = _ou_step(kbu, dev.noise_u.bias, anchor.noise_u.bias + ramp,
                      cfg.theta, cfg.sigma_phase, dt)
    bias_v = _ou_step(kbv, dev.noise_v.bias, anchor.noise_v.bias + ramp,
                      cfg.theta, cfg.sigma_phase, dt)
    gamma_u = _ou_step(kgu, dev.noise_u.gamma, anchor.noise_u.gamma,
                       cfg.theta, cfg.sigma_gamma, dt)
    gamma_v = _ou_step(kgv, dev.noise_v.gamma, anchor.noise_v.gamma,
                       cfg.theta, cfg.sigma_gamma, dt)

    new_dev = DeviceRealization(
        noise_u=PhaseNoise(gamma=gamma_u, bias=bias_u),
        noise_v=PhaseNoise(gamma=gamma_v, bias=bias_v),
        d_u=dev.d_u, d_v=dev.d_v)
    return DriftState(anchor=anchor, dev=new_dev, t=state.t + dt)


def advance(state: DriftState, dt: float, key: jax.Array,
            cfg: DriftConfig = DEFAULT_DRIFT) -> DriftState:
    """One drift step of size ``dt``; pure and deterministic in ``key``."""
    return _advance(state, jnp.asarray(dt, jnp.float32), key, cfg)


def bias_deviation(state: DriftState) -> jax.Array:
    """RMS phase-bias deviation from the anchor (radians) — a cheap
    scalar diagnostic of how far the device has walked."""
    du = state.dev.noise_u.bias - state.anchor.noise_u.bias
    dv = state.dev.noise_v.bias - state.anchor.noise_v.bias
    return jnp.sqrt(jnp.mean(jnp.concatenate(
        [du.reshape(-1), dv.reshape(-1)]) ** 2))
