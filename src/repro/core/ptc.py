"""Photonic tensor core (PTC) substrate: blockwise-SVD weight parametrization.

The paper stores every ``M×N`` weight as ``P×Q`` blocks of size ``k×k``,
each factorized ``W_pq = U_pq Σ_pq V*_pq`` with the unitaries realized as
MZI meshes and ``Σ`` as on-chip attenuators (paper §3.1).  This module is
the *digital twin* of that substrate:

* :func:`blockize` / :func:`unblockize` — the P×Q×k×k layout (+padding);
* :class:`PTCParams` — factor-level parameters ``(u, s, v)``; ``s`` is the
  only first-order-trainable leaf (subspace learning);
* :class:`PTCPhaseParams` — phase-level parameters (MZI rotations + sign
  diagonals) used by Identity Calibration / Parallel Mapping under noise;
* forward paths:
  - :func:`ptc_forward_blocked` — the paper-faithful photonic dataflow,
    three batched block ops ``U(Σ⊙(V* x))``;
  - :func:`ptc_forward_fused` — beyond-paper TPU path: recompose
    ``W_eff = U Σ V*`` once (``O(k·M·N)`` FLOPs, amortized over the token
    batch) and run one dense MXU matmul.

Conventions
-----------
``W`` is ``(M, N) = (out, in)``; a linear layer computes ``y = x @ W.T``.
Blocks: ``w_blocks[p, q] = W[p·k:(p+1)·k, q·k:(q+1)·k]``.
``v`` stores ``V*`` directly, i.e. ``W_pq = u[p,q] @ diag(s[p,q]) @ v[p,q]``.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import unitary as un
from .noise import NoiseModel, PhaseNoise, apply_phase_noise

__all__ = [
    "PTCParams",
    "PTCPhaseParams",
    "blockize",
    "unblockize",
    "pad_to_blocks",
    "svd_factorize",
    "random_factorize",
    "identity_factorize",
    "compose_weight",
    "block_energy",
    "ptc_forward_blocked",
    "ptc_forward_fused",
    "ptc_forward",
    "phases_to_factors",
    "factors_to_phases",
]


class PTCParams(NamedTuple):
    """Factor-level PTC parameters for one logical weight matrix.

    u: (P, Q, k, k)  left singular bases  (frozen after mapping/init)
    s: (P, Q, k)     singular values      (the subspace-trainable leaf)
    v: (P, Q, k, k)  right bases, stored as V* (acts directly on x)
    """

    u: jax.Array
    s: jax.Array
    v: jax.Array

    @property
    def k(self) -> int:
        return self.u.shape[-1]

    @property
    def grid(self) -> tuple[int, int]:
        return self.u.shape[0], self.u.shape[1]


class PTCPhaseParams(NamedTuple):
    """Phase-level PTC parameters (the physical control variables).

    phi_u / phi_v: (P, Q, T) MZI rotation phases, T = k(k-1)/2
    d_u / d_v:     (P, Q, k) ±1 sign diagonals
    s:             (P, Q, k) singular values (attenuator settings)
    """

    phi_u: jax.Array
    d_u: jax.Array
    phi_v: jax.Array
    d_v: jax.Array
    s: jax.Array


# ---------------------------------------------------------------------------
# Blocking layout
# ---------------------------------------------------------------------------


def pad_to_blocks(m: int, k: int) -> int:
    return (m + k - 1) // k * k


def blockize(w: jax.Array, k: int) -> jax.Array:
    """(M, N) → (P, Q, k, k), zero-padding trailing edges."""
    m, n = w.shape
    mp, np_ = pad_to_blocks(m, k), pad_to_blocks(n, k)
    if (mp, np_) != (m, n):
        w = jnp.pad(w, ((0, mp - m), (0, np_ - n)))
    return w.reshape(mp // k, k, np_ // k, k).transpose(0, 2, 1, 3)


def unblockize(blocks: jax.Array, m: int | None = None,
               n: int | None = None) -> jax.Array:
    """(P, Q, k, k) → (M, N), cropping any padding."""
    p, q, k, _ = blocks.shape
    w = blocks.transpose(0, 2, 1, 3).reshape(p * k, q * k)
    if m is not None or n is not None:
        w = w[: m if m is not None else p * k, : n if n is not None else q * k]
    return w


# ---------------------------------------------------------------------------
# Factorizations
# ---------------------------------------------------------------------------


def svd_factorize(w: jax.Array, k: int) -> PTCParams:
    """Blockwise SVD of a dense weight — the Parallel-Mapping target init."""
    blocks = blockize(w, k)
    u, s, vh = jnp.linalg.svd(blocks, full_matrices=False)
    return PTCParams(u=u, s=s, v=vh)


def random_factorize(key: jax.Array, m: int, n: int, k: int,
                     scale: float | None = None,
                     dtype=jnp.float32) -> PTCParams:
    """Random-orthogonal bases + scaled singular values (train-from-scratch).

    ``scale`` defaults to sqrt(2/(M+N)) Glorot-normal-matched: with Haar
    bases, E[W_ij²] = E[s²]/k, so s ~ N(0, k·σ_w²) matches a dense Glorot
    init element-wise.
    """
    p, q = pad_to_blocks(m, k) // k, pad_to_blocks(n, k) // k
    ku, kv, ks = jax.random.split(key, 3)
    u = _random_orthogonal_batch(ku, (p, q), k, dtype)
    v = _random_orthogonal_batch(kv, (p, q), k, dtype)
    if scale is None:
        scale = float(np.sqrt(2.0 / (m + n)))
    s = scale * np.sqrt(k) * jax.random.normal(ks, (p, q, k), dtype)
    return PTCParams(u=u, s=s, v=v)


def identity_factorize(m: int, n: int, k: int, dtype=jnp.float32) -> PTCParams:
    """U = V* = I, Σ = 1 — the post-Identity-Calibration circuit state."""
    p, q = pad_to_blocks(m, k) // k, pad_to_blocks(n, k) // k
    eye = jnp.broadcast_to(jnp.eye(k, dtype=dtype), (p, q, k, k))
    return PTCParams(u=eye, s=jnp.ones((p, q, k), dtype), v=eye)


def _random_orthogonal_batch(key: jax.Array, batch: tuple[int, ...], k: int,
                             dtype) -> jax.Array:
    g = jax.random.normal(key, batch + (k, k), jnp.float32)
    qm, rm = jnp.linalg.qr(g)
    qm = qm * jnp.sign(jnp.diagonal(rm, axis1=-2, axis2=-1))[..., None, :]
    return qm.astype(dtype)


# ---------------------------------------------------------------------------
# Weight (re)composition and forward paths
# ---------------------------------------------------------------------------


def compose_weight(params: PTCParams) -> jax.Array:
    """W_pq = U diag(s) V* for every block → (P, Q, k, k).

    Cost 2·k·M·N FLOPs — amortized over the token batch in the fused path.
    """
    us = params.u * params.s[..., None, :]
    return us @ params.v


def block_energy(params: PTCParams) -> jax.Array:
    """‖W_pq‖_F² = Tr(|Σ_pq|²) — the btopk sampling score (paper §3.4.2)."""
    return jnp.sum(params.s.astype(jnp.float32) ** 2, axis=-1)


def _block_x(x: jax.Array, q: int, k: int) -> jax.Array:
    """(..., N) → (..., Q, k) with zero-padding."""
    n = x.shape[-1]
    if q * k != n:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, q * k - n)])
    return x.reshape(x.shape[:-1] + (q, k))


def ptc_forward_blocked(params: PTCParams, x: jax.Array,
                        out_dim: int | None = None) -> jax.Array:
    """Paper-faithful photonic dataflow: y_p = Σ_q U_pq (s_pq ⊙ (V*_pq x_q)).

    Three batched block ops — exactly the three physical stages of the PTC
    (input mesh, attenuators, output mesh) plus the electronic cross-PTC
    partial-product accumulation over q.
    """
    p, q = params.grid
    k = params.k
    xb = _block_x(x, q, k)                                   # (..., Q, k)
    yv = jnp.einsum("pqkj,...qj->...pqk", params.v, xb)      # V* x
    ys = yv * params.s                                       # Σ ⊙ ·
    y = jnp.einsum("pqik,...pqk->...pqi", params.u, ys)      # U ·
    y = y.sum(-2).reshape(x.shape[:-1] + (p * k,))           # Σ_q accumulate
    if out_dim is not None and out_dim != p * k:
        y = y[..., :out_dim]
    return y


def ptc_forward_fused(params: PTCParams, x: jax.Array,
                      out_dim: int | None = None) -> jax.Array:
    """Beyond-paper TPU path: recompose W_eff once, one dense matmul."""
    p, q = params.grid
    k = params.k
    w = unblockize(compose_weight(params))                   # (P·k, Q·k)
    n = x.shape[-1]
    if q * k != n:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, q * k - n)])
    y = x @ w.T
    if out_dim is not None and out_dim != p * k:
        y = y[..., :out_dim]
    return y


def ptc_forward(params: PTCParams, x: jax.Array, *, mode: str = "fused",
                out_dim: int | None = None) -> jax.Array:
    if mode == "fused":
        return ptc_forward_fused(params, x, out_dim)
    if mode == "blocked":
        return ptc_forward_blocked(params, x, out_dim)
    raise ValueError(f"unknown ptc forward mode: {mode!r}")


# ---------------------------------------------------------------------------
# Phase-level ↔ factor-level bridges (used by IC / PM / noise experiments)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("kind", "model"))
def phases_to_factors(phase_params: PTCPhaseParams,
                      noise_u: PhaseNoise | None = None,
                      noise_v: PhaseNoise | None = None,
                      *, kind: str = "clements",
                      model: NoiseModel | None = None) -> PTCParams:
    """Materialize the (optionally noisy) realized factors from phases.

    This is the simulator's "physical" read-out: the unitaries that the
    mesh actually implements once ``Ω Γ Q(Φ) + Φ_b`` is applied.
    """
    k = phase_params.d_u.shape[-1]
    spec = un.mesh_spec(k, kind)
    phi_u, phi_v = phase_params.phi_u, phase_params.phi_v
    if model is not None and model.enabled:
        assert noise_u is not None and noise_v is not None
        phi_u = apply_phase_noise(spec, phi_u, noise_u, model)
        phi_v = apply_phase_noise(spec, phi_v, noise_v, model)
    u = un.build_unitary(spec, phi_u, phase_params.d_u)
    v = un.build_unitary(spec, phi_v, phase_params.d_v)
    return PTCParams(u=u, s=phase_params.s, v=v)


def factors_to_phases(params: PTCParams, kind: str = "clements",
                      ) -> PTCPhaseParams:
    """Exact per-block mesh decomposition (numpy, float64) of ideal factors."""
    p, q = params.grid
    k = params.k
    u_np = np.asarray(params.u, dtype=np.float64)
    v_np = np.asarray(params.v, dtype=np.float64)
    t = un.num_phases(k)
    phi_u = np.zeros((p, q, t))
    phi_v = np.zeros((p, q, t))
    d_u = np.zeros((p, q, k))
    d_v = np.zeros((p, q, k))
    for i in range(p):
        for j in range(q):
            phi_u[i, j], d_u[i, j] = un.decompose(u_np[i, j], kind)
            phi_v[i, j], d_v[i, j] = un.decompose(v_np[i, j], kind)
    f32 = lambda a: jnp.asarray(a, dtype=jnp.float32)
    return PTCPhaseParams(phi_u=f32(phi_u), d_u=f32(d_u), phi_v=f32(phi_v),
                          d_v=f32(d_v), s=params.s)
