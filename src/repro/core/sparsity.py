"""Multi-level sparsity for in-situ subspace gradients (paper §3.4.2).

Three levels, each an unbiased (or deliberately-scaled) estimator:

* **Feedback sampling** — structured block mask on the feedback matrix
  ``W^T``: ``P_W = c_W (S_W ⊗ 1)``, ``S_W ∈ {0,1}^{Q×P}``.  Strategies:
  - ``uniform`` — iid Bernoulli(α) blocks;
  - ``topk``    — global greedy top-⌈αQP⌉ by block energy (biased, can
                  load-imbalance the accumulation paths);
  - ``btopk``   — the paper's *balanced* top-K: exactly ⌈αP⌉ blocks per
                  row of W^T (same sparsity every row ⇒ equal partial-sum
                  depth on every output), guided by block energy with
                  Gumbel perturbation (a guided distribution, not pure
                  greedy — trades bias for variance).
  Normalizations: ``none``, ``exp`` (expectation-maintained, ×1/α — the
  unbiased choice, Appendix D), ``var`` (variance-maintained, ×1/√α).

* **Column sampling** — drop im2col columns / tokens of the gradient
  contraction ``δyᵀ·x`` with a shared-across-batch mask.  For LM archs the
  "columns" are tokens (DESIGN §4).

* **Data sampling (SMD)** — skip a whole iteration w.p. α_D
  (:func:`smd_keep_iteration`), a pure scheduler-level knob.

All masks are sampled OUTSIDE the custom_vjp and passed in as arrays so
the in-situ backward stays a pure function of its inputs.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = [
    "SparsityConfig",
    "DENSE",
    "feedback_mask",
    "column_mask",
    "smd_keep_iteration",
    "accumulation_depths",
]


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """Static sampling configuration for one training run."""

    alpha_w: float = 1.0            # feedback density (1.0 = dense)
    feedback_mode: str = "btopk"    # uniform | topk | btopk
    feedback_norm: str = "exp"      # none | exp | var
    alpha_c: float = 1.0            # column/token density
    column_norm: str = "none"       # paper adopts α_C-scale off (§3.4.2)
    alpha_d: float = 0.0            # SMD iteration-skip probability

    @property
    def enabled(self) -> bool:
        return self.alpha_w < 1.0 or self.alpha_c < 1.0

    def normalizer(self, alpha: float, kind: str) -> float:
        if kind == "none" or alpha >= 1.0:
            return 1.0
        if kind == "exp":
            return 1.0 / alpha
        if kind == "var":
            return 1.0 / float(jnp.sqrt(alpha))
        raise ValueError(f"unknown normalization: {kind!r}")


DENSE = SparsityConfig()


def _row_balanced_topk(scores: jax.Array, keep: int) -> jax.Array:
    """Keep the ``keep`` largest entries of every row → boolean mask.

    Uses lax.top_k (argsort+slice hits a gather-transpose issue when the
    scores sit on a stop-gradient path inside jax.grad)."""
    q, p = scores.shape
    _, idx = jax.lax.top_k(scores, keep)
    mask = jnp.zeros((q, p), dtype=bool)
    rows = jnp.arange(q)[:, None]
    return mask.at[rows, idx].set(True)


def feedback_mask(key: jax.Array, block_energy: jax.Array,
                  cfg: SparsityConfig) -> jax.Array:
    """Sample ``S_W ∈ {0,1}^{Q×P}`` — mask over blocks of ``W^T``.

    ``block_energy`` is ‖W_pq‖_F² with shape (P, Q) (forward-block layout);
    the mask indexes the FEEDBACK orientation (Q, P) = blocks of W^T.
    Returns a float mask already scaled by the normalizer c_W.
    """
    p, q = block_energy.shape
    alpha = cfg.alpha_w
    if alpha >= 1.0:
        return jnp.ones((q, p), dtype=jnp.float32)
    scores = block_energy.T.astype(jnp.float32)  # (Q, P)
    keep = max(1, int(round(alpha * p)))
    if cfg.feedback_mode == "uniform":
        # exactly-keep uniform per row (load-balanced by construction, the
        # importance-UNAWARE baseline the paper compares against)
        noise = jax.random.uniform(key, (q, p))
        mask = _row_balanced_topk(noise, keep)
    elif cfg.feedback_mode == "topk":
        # global greedy: top ⌈αPQ⌉ blocks regardless of row — biased and
        # load-imbalanced (paper Fig. 7)
        total = max(1, int(round(alpha * p * q)))
        flat = scores.reshape(-1)
        idx = jnp.argsort(flat, descending=True)[:total]
        mask = jnp.zeros((q * p,), dtype=bool).at[idx].set(True).reshape(q, p)
    elif cfg.feedback_mode == "btopk":
        # guided distribution: energy + Gumbel noise, row-balanced top-K
        g = -jnp.log(-jnp.log(jax.random.uniform(
            key, (q, p), minval=1e-20, maxval=1.0)))
        guided = jnp.log(scores + 1e-12) + g
        mask = _row_balanced_topk(guided, keep)
    else:
        raise ValueError(f"unknown feedback mode: {cfg.feedback_mode!r}")
    c_w = cfg.normalizer(keep / p, cfg.feedback_norm)
    return mask.astype(jnp.float32) * c_w


def column_mask(key: jax.Array, n_cols: int, cfg: SparsityConfig) -> jax.Array:
    """Shared-across-batch column/token mask, scaled by the column norm."""
    if cfg.alpha_c >= 1.0:
        return jnp.ones((n_cols,), dtype=jnp.float32)
    keep = max(1, int(round(cfg.alpha_c * n_cols)))
    idx = jax.random.choice(key, n_cols, (keep,), replace=False)
    mask = jnp.zeros((n_cols,), dtype=jnp.float32).at[idx].set(1.0)
    return mask * cfg.normalizer(keep / n_cols, cfg.column_norm)


def smd_keep_iteration(key: jax.Array, cfg: SparsityConfig) -> jax.Array:
    """Stochastic mini-batch dropping: True = run this iteration."""
    if cfg.alpha_d <= 0.0:
        return jnp.asarray(True)
    return jax.random.uniform(key, ()) >= cfg.alpha_d


def accumulation_depths(mask: jax.Array) -> jax.Array:
    """Per-output-row partial-product chain length (latency model, Fig. 7).

    The feedback latency is bottlenecked by the LONGEST accumulation path —
    btopk equalizes these by construction.
    """
    return (mask > 0).sum(axis=-1)
