"""Optical circuit non-ideality models (paper §3.1, Appendix A.3).

The noisy effective phases follow the paper's composition
``W(Ω Γ Q(Φ) + Φ_b)``:

* ``Q(·)``  — b-bit uniform quantization of the rotation phases in [0, 2π);
* ``Γ``     — static multiplicative phase-shifter variation, one factor per
              shifter, ``γ_mult ~ N(1, σ_γ²)`` (σ_γ = 0.002 default);
* ``Ω``     — thermal crosstalk: adjacent MZIs in the same mesh column couple
              with coefficient 0.005 (self coupling 1);
* ``Φ_b``   — unknown static phase bias ``~ U(0, 2π)`` from manufacturing.

Γ and Φ_b are *device realizations*: sampled once per PTC instance and held
fixed, which is what makes calibration (IC) necessary.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .unitary import MeshSpec

__all__ = ["NoiseModel", "PhaseNoise", "sample_phase_noise", "quantize_phase",
           "crosstalk_couple", "apply_phase_noise", "IDEAL", "DEFAULT_NOISE"]

TWO_PI = 2.0 * np.pi


@dataclasses.dataclass(frozen=True)
class NoiseModel:
    """Static configuration of circuit non-idealities."""

    enabled: bool = True
    phase_bits: int | None = 8      # Q(·) resolution for U/V* rotation phases
    sigma_bits: int | None = None   # Σ control resolution (None = analog/high)
    gamma_std: float = 0.002        # phase-shifter variation σ_γ
    crosstalk: float = 0.005        # adjacent-MZI mutual coupling ω
    phase_bias: bool = True         # unknown Φ_b ~ U(0, 2π)

    def off(self) -> "NoiseModel":
        return dataclasses.replace(self, enabled=False)

    def post_ic(self) -> "NoiseModel":
        """The noise frame AFTER Identity Calibration: the controller has
        learned per-device bias corrections, so commanded phases are
        issued relative to them — Φ_b is compensated; Q/Γ/Ω remain."""
        return dataclasses.replace(self, phase_bias=False)


IDEAL = NoiseModel(enabled=False)
DEFAULT_NOISE = NoiseModel()


class PhaseNoise(NamedTuple):
    """A sampled device realization for one batch of phase vectors.

    Shapes broadcast against the phase arrays they perturb, e.g.
    ``(..., n_rot)`` for per-block realizations.
    """

    gamma: jax.Array  # multiplicative, ~N(1, σ²)
    bias: jax.Array   # additive, ~U(0, 2π)


def sample_phase_noise(key: jax.Array, shape: tuple[int, ...],
                       model: NoiseModel) -> PhaseNoise:
    kg, kb = jax.random.split(key)
    if not model.enabled:
        return PhaseNoise(jnp.ones(shape), jnp.zeros(shape))
    gamma = 1.0 + model.gamma_std * jax.random.normal(kg, shape)
    if model.phase_bias:
        bias = jax.random.uniform(kb, shape, minval=0.0, maxval=TWO_PI)
    else:
        bias = jnp.zeros(shape)
    return PhaseNoise(gamma, bias)


def quantize_phase(phases: jax.Array, bits: int | None) -> jax.Array:
    """Paper Eq. (9): uniform b-bit quantization on [0, 2π)."""
    if bits is None:
        return phases
    step = TWO_PI / (2 ** bits - 1)
    return jnp.round(jnp.mod(phases, TWO_PI) / step) * step


def crosstalk_couple(spec: MeshSpec, phases: jax.Array,
                     omega: float) -> jax.Array:
    """φ_c = Ω φ — add ω · (sum of same-column neighbour phases)."""
    if omega == 0.0:
        return phases
    neigh = jnp.asarray(spec.phase_neighbors)  # (T, 2), -1 padded
    gathered = jnp.take(phases, jnp.maximum(neigh, 0), axis=-1)  # (..., T, 2)
    gathered = jnp.where(neigh >= 0, gathered, 0.0)
    return phases + omega * gathered.sum(-1)


def apply_phase_noise(spec: MeshSpec, phases: jax.Array, noise: PhaseNoise,
                      model: NoiseModel) -> jax.Array:
    """Effective phases ``Ω Γ Q(Φ) + Φ_b`` fed to the physical mesh."""
    if not model.enabled:
        return phases
    q = quantize_phase(phases, model.phase_bits)
    v = noise.gamma * q
    c = crosstalk_couple(spec, v, model.crosstalk)
    return c + noise.bias
