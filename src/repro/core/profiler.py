"""Hardware cost profiler: the paper's Appendix-G PTC energy / step model.

The paper's simulator "counts the total number of PTC calls as the
normalized energy indicator and the longest accumulation path as the
normalized latency/runtime indicator".  We reproduce that cost model so
the Table-2 / Fig-10 / Fig-11 benchmarks can be emitted:

Energy (PTC calls), per layer with P×Q blocks and n_cols = B·H'·W'
streamed input columns (tokens for LM layers, im2col columns for CONV):

    E_fwd  = P·Q·n_cols
    E_∇Σ   = 2 · P·Q · (α_C·n_cols)      (2 reciprocal PTC passes, Eq. 5)
    E_∇x   = (keep_W·P)·Q · n_cols       (masked feedback blocks idle)

Time steps (k adders per PTC, sequential cross-PTC reduction, parallel
local accumulation; PTC call = 1 step, each partial-product accumulation
stage = 1 step, Hadamard = 1 step):

    T_fwd  = n_cols · (1 + Q)            (Q-deep partial-sum chain)
    T_∇Σ   = α_C·n_cols · 3              (2 parallel PTC passes + Hadamard,
                                          local accumulation pipelined)
    T_∇x   = n_cols · (1 + L_max)        (L_max = LONGEST accumulation path
                                          over rows of the masked W^T — the
                                          Fig-7 load-balance bottleneck
                                          btopk equalizes)

Only the RATIOS are meaningful (the paper's units are normalized too);
``sampling_table2`` reports totals in G-calls to match Table 2's scale.
Note on α conventions: our ``SparsityConfig`` stores KEEP densities;
the paper's table annotations quote drop sparsities (their α=0.6 row
means keep 0.4 — verified against Table 2's 8.34→3.38 ∇x energy).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable


from .sparsity import SparsityConfig

__all__ = ["LayerCost", "ModelCost", "LayerSpec", "layer_cost", "model_cost",
           "conv_layer_spec", "linear_layer_spec"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Shape of one PTC-mapped projection for costing purposes."""

    name: str
    c_out: int          # output channels / features (M)
    c_in_eff: int       # input channels × K² (N after im2col)
    n_cols: int         # streamed columns: B·H'·W' (conv) or B·T (LM)
    k: int = 9          # PTC block size
    first_layer: bool = False   # no ∇x needed into the data

    @property
    def grid(self) -> tuple[int, int]:
        p = -(-self.c_out // self.k)
        q = -(-self.c_in_eff // self.k)
        return p, q


def conv_layer_spec(name, c_out, c_in, ksize, batch, h_out, w_out, k=9,
                    first_layer=False) -> LayerSpec:
    return LayerSpec(name=name, c_out=c_out, c_in_eff=c_in * ksize * ksize,
                     n_cols=batch * h_out * w_out, k=k,
                     first_layer=first_layer)


def linear_layer_spec(name, d_out, d_in, n_tokens, k=9,
                      first_layer=False) -> LayerSpec:
    return LayerSpec(name=name, c_out=d_out, c_in_eff=d_in,
                     n_cols=n_tokens, k=k, first_layer=first_layer)


@dataclasses.dataclass(frozen=True)
class LayerCost:
    e_fwd: float
    e_bwd_w: float
    e_bwd_x: float
    t_fwd: float
    t_bwd_w: float
    t_bwd_x: float

    @property
    def e_total(self) -> float:
        return self.e_fwd + self.e_bwd_w + self.e_bwd_x

    @property
    def t_total(self) -> float:
        return self.t_fwd + self.t_bwd_w + self.t_bwd_x

    def __add__(self, other: "LayerCost") -> "LayerCost":
        return LayerCost(*(a + b for a, b in
                           zip(dataclasses.astuple(self),
                               dataclasses.astuple(other))))


ModelCost = LayerCost  # an aggregate is structurally identical


def layer_cost(spec: LayerSpec, cfg: SparsityConfig,
               max_path: int | None = None,
               inference_only: bool = False) -> LayerCost:
    """Cost one optimization iteration of one layer under sampling ``cfg``.

    ``max_path``: longest per-row kept-block count of the feedback mask
    (defaults to the balanced value ⌈α_W·P⌉ — btopk guarantees it; pass
    the measured value for topk to expose its load imbalance).
    """
    p, q = spec.grid
    n = spec.n_cols
    keep_w = max(1, int(round(cfg.alpha_w * p))) if cfg.alpha_w < 1.0 else p
    kept_cols = max(1, int(round(cfg.alpha_c * n))) if cfg.alpha_c < 1.0 else n
    run_frac = 1.0 - cfg.alpha_d    # SMD skips whole iterations

    e_fwd = float(p * q * n)
    if inference_only:
        return LayerCost(e_fwd, 0.0, 0.0, float(n * (1 + q)), 0.0, 0.0)

    e_bwd_w = 2.0 * p * q * kept_cols
    e_bwd_x = 0.0 if spec.first_layer else float(keep_w * q * n)

    if max_path is None:
        max_path = keep_w
    t_fwd = float(n * (1 + q))
    t_bwd_w = float(kept_cols * 3)
    t_bwd_x = 0.0 if spec.first_layer else float(n * (1 + max_path))

    return LayerCost(e_fwd * run_frac, e_bwd_w * run_frac, e_bwd_x * run_frac,
                     t_fwd * run_frac, t_bwd_w * run_frac, t_bwd_x * run_frac)


def model_cost(specs: Iterable[LayerSpec], cfg: SparsityConfig,
               iters: float = 1.0, **kw) -> LayerCost:
    total = LayerCost(0, 0, 0, 0, 0, 0)
    for s in specs:
        total = total + layer_cost(s, cfg, **kw)
    return LayerCost(*(x * iters for x in dataclasses.astuple(total)))


# -- reference model layer stacks (paper §4.1) ------------------------------


def vgg8_specs(batch: int = 128, k: int = 9) -> list[LayerSpec]:
    """VGG-8 on CIFAR-10 (32×32): conv stack + FC head."""
    cfg = [(64, 3, 32), (64, 64, 16), (128, 64, 16), (128, 128, 8),
           (256, 128, 8), (256, 256, 4)]
    specs = []
    c_prev = None
    for i, (c_out, c_in, hw) in enumerate(cfg):
        specs.append(conv_layer_spec(f"conv{i}", c_out, c_in, 3, batch, hw, hw,
                                     k=k, first_layer=(i == 0)))
    specs.append(linear_layer_spec("fc1", 512, 256 * 4 * 4 // 4, batch, k=k))
    specs.append(linear_layer_spec("fc2", 10, 512, batch, k=k))
    return specs


def resnet18_specs(batch: int = 128, k: int = 9) -> list[LayerSpec]:
    """ResNet-18 (CIFAR variant, 32×32 stem)."""
    specs = [conv_layer_spec("stem", 64, 3, 3, batch, 32, 32, k=k,
                             first_layer=True)]
    plan = [(64, 32, 2), (128, 16, 2), (256, 8, 2), (512, 4, 2)]
    c_in = 64
    for c_out, hw, blocks in plan:
        for b in range(blocks):
            specs.append(conv_layer_spec(f"c{c_out}b{b}a", c_out, c_in, 3,
                                         batch, hw, hw, k=k))
            specs.append(conv_layer_spec(f"c{c_out}b{b}b", c_out, c_out, 3,
                                         batch, hw, hw, k=k))
            c_in = c_out
    specs.append(linear_layer_spec("fc", 10, 512, batch, k=k))
    return specs
