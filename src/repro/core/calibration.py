"""Identity Calibration (IC): variation-agnostic circuit state preparation.

Paper §3.2: after manufacturing, the mesh state is unknown (phase bias
Φ_b ~ U(0,2π), variation Γ, crosstalk Ω).  The exact problem
``min ‖U−I‖ + ‖V*−I‖`` is unsolvable under the observability constraints
(only the end-to-end ``UΣV*`` is measurable); the solvable surrogate is
Eq. (2):

    min_Φ ‖ U(Φ^U) Σ_cal V*(Φ^V) Σ_cal⁻¹ − I ‖²

whose optimum is the *sign-flip identity* Ĩ (arbitrary unobservable ±1
column/row flips — harmless downstream, they cancel in OSP and in the
in-situ Σ-gradient).  ``Σ_cal`` is a fixed, known, non-degenerate
attenuator setting: distinct entries force the off-diagonals to zero.

The search is pure ZO (``repro.optim.zo``), vmapped over every k×k block
of every layer in parallel — blocks are independent physical circuits.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import unitary as un
from .noise import NoiseModel, PhaseNoise, sample_phase_noise, apply_phase_noise
from ..optim.zo import ZOConfig, zo_minimize

__all__ = ["DeviceRealization", "sample_device", "ICResult",
           "calibrate_identity", "identity_mse", "calibration_sigma"]


class DeviceRealization(NamedTuple):
    """The fixed, unknown physical state of a batch of PTC blocks.

    Sampled once per chip; IC exists because this is not observable.
    Leading dims = block batch (e.g. (B,) flattened blocks).
    """

    noise_u: PhaseNoise     # Γ, Φ_b realizations for the U mesh
    noise_v: PhaseNoise     # ... for the V* mesh
    d_u: jax.Array          # ±1 manufacturing sign diagonals
    d_v: jax.Array


def sample_device(key: jax.Array, batch: tuple[int, ...], k: int,
                  model: NoiseModel, kind: str = "clements"
                  ) -> DeviceRealization:
    spec = un.mesh_spec(k, kind)
    t = spec.n_rot
    ku, kv, kd1, kd2 = jax.random.split(key, 4)
    nu = sample_phase_noise(ku, batch + (t,), model)
    nv = sample_phase_noise(kv, batch + (t,), model)
    d_u = jnp.where(jax.random.bernoulli(kd1, 0.5, batch + (k,)), 1.0, -1.0)
    d_v = jnp.where(jax.random.bernoulli(kd2, 0.5, batch + (k,)), 1.0, -1.0)
    return DeviceRealization(noise_u=nu, noise_v=nv, d_u=d_u, d_v=d_v)


def calibration_sigma(k: int, n_probes: int = 3, seed: int = 7) -> jax.Array:
    """Known non-degenerate Σ_cal attenuator settings, (n_probes, k).

    Probing with SEVERAL distinct diagonals (permutations of a linspace)
    is essential: with a single Σ the surrogate Eq. (2) has a *quartic*
    valley of near-optima ``U ≈ polar(Σ V Σ⁻¹)`` with non-diagonal V;
    a second/third probe with non-coinciding σ-ratios turns the valley
    quadratic and lets ZO reach the paper's MSE ≈ 0.013 (Table 4).  Σ is
    freely and precisely tunable on chip (§2 "only Σ can be precisely
    monitored and efficiently tuned"), so multi-probe IC costs only
    k·n_probes extra measurements per step.
    """
    rng = np.random.default_rng(seed)
    base = np.linspace(0.5, 1.5, k)
    rows = [base] + [rng.permutation(base) for _ in range(n_probes - 1)]
    return jnp.asarray(np.stack(rows), dtype=jnp.float32)


def realized_unitaries(spec: un.MeshSpec, phi_u, phi_v,
                       dev: DeviceRealization, model: NoiseModel):
    """The unitaries the physical mesh actually implements for commanded Φ."""
    pu = apply_phase_noise(spec, phi_u, dev.noise_u, model)
    pv = apply_phase_noise(spec, phi_v, dev.noise_v, model)
    u = un.build_unitary(spec, pu, dev.d_u)
    v = un.build_unitary(spec, pv, dev.d_v)
    return u, v


class ICResult(NamedTuple):
    phi_u: jax.Array      # commanded phases, (..., T)
    phi_v: jax.Array
    u: jax.Array          # realized Ĩ_U, (..., k, k)
    v: jax.Array          # realized Ĩ_V
    loss: jax.Array       # final surrogate loss per block
    mse_u: jax.Array      # ‖|U|−I‖² MSE per block (Table 4 metric)
    mse_v: jax.Array
    history: jax.Array    # best-loss traces, (..., steps//record)


def identity_mse(u: jax.Array) -> jax.Array:
    k = u.shape[-1]
    eye = jnp.eye(k, dtype=u.dtype)
    return jnp.mean((jnp.abs(u) - eye) ** 2, axis=(-2, -1))


def calibrate_identity(key: jax.Array, n_blocks: int, k: int,
                       model: NoiseModel, *, kind: str = "clements",
                       method: str = "zcd",
                       cfg: ZOConfig | None = None,
                       dev: DeviceRealization | None = None,
                       n_sigma: int = 3, restarts: int = 4) -> ICResult:
    """Run IC on ``n_blocks`` independent k×k PTCs in parallel.

    One physical loss measurement = probing the PTC with the k unit
    vectors per Σ_cal setting (coherent I/O) and comparing against
    Σ_cal — simulated by materializing the realized transfer matrix.
    The search uses ``restarts`` cyclic step-size restarts (δ₀ halves
    each cycle), which escapes the surrogate's flat directions.
    """
    spec = un.mesh_spec(k, kind)
    t = spec.n_rot
    if cfg is None:
        # total probe budget ≈ 28·2T per restart cycle (the paper's 400
        # "epochs" correspond to ~2T coordinate probes each)
        cfg = ZOConfig(steps=max(500, 28 * t), inner=2 * t,
                       delta0=0.5, decay=1.05)
    kd, ko = jax.random.split(key)
    if dev is None:
        dev = sample_device(kd, (n_blocks,), k, model, kind)
    sigs = calibration_sigma(k, n_probes=n_sigma)
    eye = jnp.eye(k)

    def loss_fn(phi, dev_b):
        phi_u, phi_v = phi[:t], phi[t:]
        u, v = realized_unitaries(spec, phi_u, phi_v, dev_b, model)
        # observable surrogate: intensity distance (|·|, phase-insensitive)
        l = 0.0
        for i in range(sigs.shape[0]):
            m = ((u * sigs[i]) @ v) / sigs[i]   # U Σ V* Σ⁻¹, Σ⁻¹ electronic
            l = l + jnp.mean((jnp.abs(m) - eye) ** 2)
        return l / sigs.shape[0]

    x = jnp.zeros((n_blocks, 2 * t))
    histories = []
    for r in range(restarts):
        keys = jax.random.split(jax.random.fold_in(ko, r), n_blocks)
        cfg_r = cfg._replace(delta0=cfg.delta0 / (2.0 ** r))

        def solve_one(x0_b, key_b, dev_b):
            return zo_minimize(lambda p: loss_fn(p, dev_b), x0_b, key_b,
                               cfg_r, method=method)

        res = jax.jit(jax.vmap(solve_one))(x, keys, dev)
        x = res.x
        histories.append(res.history)
    phi_u, phi_v = x[:, :t], x[:, t:]
    u, v = realized_unitaries(spec, phi_u, phi_v, dev, model)
    return ICResult(phi_u=phi_u, phi_v=phi_v, u=u, v=v, loss=res.f,
                    mse_u=identity_mse(u), mse_v=identity_mse(v),
                    history=jnp.concatenate(histories, axis=-1))
