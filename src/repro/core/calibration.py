"""Identity Calibration (IC): variation-agnostic circuit state preparation.

Paper §3.2: after manufacturing, the mesh state is unknown (phase bias
Φ_b ~ U(0,2π), variation Γ, crosstalk Ω).  The exact problem
``min ‖U−I‖ + ‖V*−I‖`` is unsolvable under the observability constraints
(only the end-to-end ``UΣV*`` is measurable); the solvable surrogate is
Eq. (2):

    min_Φ ‖ U(Φ^U) Σ_cal V*(Φ^V) Σ_cal⁻¹ − I ‖²

whose optimum is the *sign-flip identity* Ĩ (arbitrary unobservable ±1
column/row flips — harmless downstream, they cancel in OSP and in the
in-situ Σ-gradient).  ``Σ_cal`` is a fixed, known, non-degenerate
attenuator setting: distinct entries force the off-diagonals to zero.

This module is pure control-plane code: it decides the Σ_cal schedule
and the ZO budget, then requests the in-situ search as a
``driver.run_ic`` job through the :class:`~repro.hw.driver.PhotonicDriver`
boundary — it never touches the device realization itself (the guard
test in ``tests/test_driver.py`` enforces that).  Pass ``driver=`` to
calibrate real/remote hardware; by default an in-process digital twin is
sampled, which reproduces the pre-driver seed behavior exactly.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..optim.zo import ZOConfig

__all__ = ["ICResult", "calibrate_identity", "identity_mse",
           "calibration_sigma"]


def calibration_sigma(k: int, n_probes: int = 3, seed: int = 7) -> jax.Array:
    """Known non-degenerate Σ_cal attenuator settings, (n_probes, k).

    Probing with SEVERAL distinct diagonals (permutations of a linspace)
    is essential: with a single Σ the surrogate Eq. (2) has a *quartic*
    valley of near-optima ``U ≈ polar(Σ V Σ⁻¹)`` with non-diagonal V;
    a second/third probe with non-coinciding σ-ratios turns the valley
    quadratic and lets ZO reach the paper's MSE ≈ 0.013 (Table 4).  Σ is
    freely and precisely tunable on chip (§2 "only Σ can be precisely
    monitored and efficiently tuned"), so multi-probe IC costs only
    k·n_probes extra measurements per step.
    """
    rng = np.random.default_rng(seed)
    base = np.linspace(0.5, 1.5, k)
    rows = [base] + [rng.permutation(base) for _ in range(n_probes - 1)]
    return jnp.asarray(np.stack(rows), dtype=jnp.float32)


class ICResult(NamedTuple):
    phi_u: jax.Array      # commanded phases, (..., T)
    phi_v: jax.Array
    u: jax.Array          # realized Ĩ_U readback, (..., k, k)
    v: jax.Array          # realized Ĩ_V
    loss: jax.Array       # final surrogate loss per block
    mse_u: jax.Array      # ‖|U|−I‖² MSE per block (Table 4 metric)
    mse_v: jax.Array
    history: jax.Array    # best-loss traces, (..., steps//record)


def identity_mse(u: jax.Array) -> jax.Array:
    k = u.shape[-1]
    eye = jnp.eye(k, dtype=u.dtype)
    return jnp.mean((jnp.abs(u) - eye) ** 2, axis=(-2, -1))


def calibrate_identity(key: jax.Array, n_blocks: int, k: int,
                       model=None, *, kind: str = "clements",
                       method: str = "zcd",
                       cfg: ZOConfig | None = None,
                       dev=None, n_sigma: int = 3, restarts: int = 4,
                       driver=None) -> ICResult:
    """Run IC on ``n_blocks`` independent k×k PTCs in parallel.

    One physical loss measurement = probing the PTC with the k unit
    vectors per Σ_cal setting (coherent I/O) and comparing against
    Σ_cal — executed by the device's local controller as a
    ``driver.run_ic`` job.  The search uses ``restarts`` cyclic
    step-size restarts (δ₀ halves each cycle), which escapes the
    surrogate's flat directions.

    ``driver``: any :class:`~repro.hw.driver.PhotonicDriver`; when
    omitted, a fresh in-process twin is sampled (``dev`` optionally
    pins its realization — forwarded opaquely, never inspected here).
    """
    kd, ko = jax.random.split(key)
    if driver is None:
        from ..hw import make_twin    # lazy: hw sits above core
        driver = make_twin(kd, n_blocks, k, model, kind, dev=dev)
    elif (driver.n_blocks, driver.k) != (n_blocks, k):
        raise ValueError(
            f"driver hosts {driver.n_blocks} blocks of k={driver.k}, "
            f"caller asked for {n_blocks} blocks of k={k}")
    k = driver.k
    from . import unitary as un
    t_rot = un.mesh_spec(k, driver.kind).n_rot
    if cfg is None:
        # total probe budget ≈ 28·2T per restart cycle (the paper's 400
        # "epochs" correspond to ~2T coordinate probes each)
        cfg = ZOConfig(steps=max(500, 28 * t_rot), inner=2 * t_rot,
                       delta0=0.5, decay=1.05)
    sigs = calibration_sigma(k, n_probes=n_sigma)
    res = driver.run_ic(ko, sigs, cfg, restarts=restarts, method=method)
    return ICResult(phi_u=res.phi[:, :t_rot], phi_v=res.phi[:, t_rot:],
                    u=res.u, v=res.v, loss=res.loss,
                    mse_u=identity_mse(res.u), mse_v=identity_mse(res.v),
                    history=res.history)
