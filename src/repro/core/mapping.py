"""Parallel Mapping (PM): alternate-projection model deployment (§3.3).

Maps pre-trained weights onto the noisy MZI meshes with high fidelity as
a *batched blockwise regression* (Eq. 3) — every k×k block is an
independent deterministic sub-problem, solved in parallel (the paper's
scalability insight #1: "decoupling ZOO from stochasticity and
partitioning ... into a batch of sub-tasks").

Per block (Algorithm 1):
1. SVD + exact mesh parametrization (UP∘SVD) — the *commanded* phases;
   under Γ/Ω/Q/Φ_b the realized mesh differs.
2. Alternate ZCD on Φ^U / Φ^V against ``‖W̃_pq(Φ) − W_pq‖²``, step size
   bounded by phase resolution, exponentially decayed — requested as an
   in-situ ``driver.zo_refine`` job.
3. **Optimal Singular-value Projection (OSP)**, Claim 1:
   ``Σ_opt = diag(U* W V)`` — analytically optimal given the (noisy,
   sign-flipped) realized bases; on chip it is two reciprocal PTC probes
   (``driver.readback_bases``), and the sign flips cancel on the diagonal.

Like IC, this is pure control-plane code: every device interaction goes
through the :class:`~repro.hw.driver.PhotonicDriver` boundary (probe,
write, readback, job) — pass ``driver=`` to deploy onto real/remote
hardware; the default in-process twin reproduces pre-driver seeds.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import unitary as un
from .ptc import PTCParams, blockize, svd_factorize
from ..optim.zo import ZOConfig

__all__ = ["PMResult", "parallel_map", "osp", "matrix_distance"]


class PMResult(NamedTuple):
    params: PTCParams       # realized factors after PM (+OSP): deployable state
    phi_u: jax.Array        # commanded phases
    phi_v: jax.Array
    err_init: jax.Array     # normalized ‖W̃−W‖²/‖W‖² at commanded-SVD init
    err_zo: jax.Array       # ... after alternate ZO
    err_osp: jax.Array      # ... after OSP (the Fig. 5 "error drop")
    history: jax.Array
    driver: object          # the PhotonicDriver the weight was deployed on


def matrix_distance(w_hat: jax.Array, w: jax.Array) -> jax.Array:
    """Normalized matrix distance ‖W−W̃‖²/‖W‖² (paper Fig. 5 metric)."""
    num = jnp.sum((w_hat - w) ** 2, axis=(-2, -1))
    den = jnp.sum(w ** 2, axis=(-2, -1)) + 1e-12
    return num / den


def osp(u: jax.Array, v: jax.Array, w: jax.Array) -> jax.Array:
    """Claim 1: Σ_opt = diag(U* W V) with V* stored in ``v``.

    Sign flips Ĩ in U/V cancel on the diagonal — so this works verbatim
    with IC/PM's sign-ambiguous realized bases.
    """
    return jnp.einsum("...ji,...jl,...il->...i", u, w, v)


def parallel_map(key: jax.Array, w: jax.Array, k: int, model=None, *,
                 kind: str = "clements", method: str = "zcd",
                 cfg: ZOConfig | None = None,
                 dev=None, run_zo: bool = True, driver=None,
                 block_range: tuple[int, int] | None = None) -> PMResult:
    """Map a dense weight ``w`` (M, N) onto noisy k×k PTC blocks.

    Returns the REALIZED factor-level parameters — the state subspace
    learning starts from.  ``run_zo=False`` skips stage 2 (commanded-SVD
    + OSP only), the cheap deployment mode for large models where Σ
    absorbs most of the residual (paper Fig. 13: SL tolerates mapping
    suboptimality).

    ``driver``: any :class:`~repro.hw.driver.PhotonicDriver` with
    ``n_blocks`` matching the P·Q grid of ``w``; when omitted, a fresh
    in-process twin is sampled (``dev`` optionally pins its realization,
    forwarded opaquely).

    ``block_range``: deploy onto the tenant slice ``(start, stop)`` of
    a shared (multi-tenant) chip instead of the whole block batch —
    requires an explicit ``driver`` whose capacity covers the range;
    every device interaction below is then scoped to those blocks, so
    co-resident tenants' state is untouched.
    """
    spec = un.mesh_spec(k, kind)
    t = spec.n_rot
    ideal = svd_factorize(w, k)
    p, q = ideal.grid
    b = p * q
    w_blocks = blockize(w, k).reshape(b, k, k)

    # Step 1 — exact parametrization of the ideal factors (numpy, fp64).
    phi_u0 = np.zeros((b, t))
    phi_v0 = np.zeros((b, t))
    d_u0 = np.zeros((b, k))
    d_v0 = np.zeros((b, k))
    u_np = np.asarray(ideal.u, np.float64).reshape(b, k, k)
    v_np = np.asarray(ideal.v, np.float64).reshape(b, k, k)
    for i in range(b):
        phi_u0[i], d_u0[i] = un.decompose(u_np[i], kind)
        phi_v0[i], d_v0[i] = un.decompose(v_np[i], kind)

    kd, ko = jax.random.split(key)
    if driver is None:
        if block_range is not None:
            raise ValueError("block_range deployment needs an explicit "
                             "driver (the shared multi-tenant chip)")
        from ..hw import make_twin    # lazy: hw sits above core
        driver = make_twin(kd, b, k, model, kind, m=w.shape[0],
                           n=w.shape[1], dev=dev)
    if block_range is None and driver.n_blocks != b:
        raise ValueError(f"driver hosts {driver.n_blocks} blocks, "
                         f"weight needs {b}")
    if block_range is not None and block_range[1] - block_range[0] != b:
        raise ValueError(f"block_range {block_range!r} spans "
                         f"{block_range[1] - block_range[0]} blocks, "
                         f"weight needs {b}")

    # deploy the commanded state: signs from the decomposition (the
    # crossing configuration is commanded; Γ/Φ_b stay the device's own)
    driver.write_signs(jnp.asarray(d_u0, jnp.float32),
                       jnp.asarray(d_v0, jnp.float32),
                       block_range=block_range)
    driver.write_phases(jnp.asarray(phi_u0, jnp.float32),
                        jnp.asarray(phi_v0, jnp.float32),
                        block_range=block_range)
    s_init = ideal.s.reshape(b, k)
    driver.write_sigma(s_init, block_range=block_range)

    from ..hw.driver import readout_blocks
    err_init = matrix_distance(readout_blocks(driver,
                                              block_range=block_range),
                               w_blocks)

    if run_zo:
        if cfg is None:
            cfg = ZOConfig(steps=max(300, 10 * t), inner=2 * t,
                           delta0=2 * np.pi / 255.0 * 8, decay=1.05)
        res = driver.zo_refine(w_blocks, ko, cfg, method=method,
                               block_range=block_range)
        phi, err_zo, history = res.phi, res.loss, res.history
    else:
        phi = jnp.concatenate([jnp.asarray(phi_u0, jnp.float32),
                               jnp.asarray(phi_v0, jnp.float32)], axis=-1)
        err_zo, history = err_init, err_init[:, None]

    # Step 3 — OSP on the realized bases (reciprocal readback probes).
    u_real, v_real = driver.readback_bases(block_range=block_range)
    s_opt = osp(u_real, v_real, w_blocks)
    w_hat = (u_real * s_opt[..., None, :]) @ v_real
    err_osp = matrix_distance(w_hat, w_blocks)
    driver.write_sigma(s_opt, block_range=block_range)

    params = PTCParams(u=u_real.reshape(p, q, k, k),
                       s=s_opt.reshape(p, q, k),
                       v=v_real.reshape(p, q, k, k))
    return PMResult(params=params, phi_u=phi[:, :t], phi_v=phi[:, t:],
                    err_init=err_init, err_zo=err_zo, err_osp=err_osp,
                    history=history, driver=driver)
