"""MZI-mesh parametrization of real orthogonal matrices.

The paper builds every unitary in ``W = U Σ V*`` from a mesh of 2×2 planar
rotators (MZIs):   ``U(k) = R_{T-1} ··· R_0 · D``   with ``T = k(k-1)/2``
adjacent-plane Givens rotations and a ±1 sign diagonal ``D``.

Two mesh topologies are supported:

* ``reck``      — triangular mesh, depth ``2k-3``; admits an *exact* numpy
                  decomposition (Givens nulling), used to initialize Parallel
                  Mapping from ``SVD(W)``.
* ``clements``  — rectangular mesh, depth ``k`` of alternating even/odd
                  "butterfly" layers; shallowest physical mesh, the layout the
                  Pallas ``mesh_apply`` kernel tiles.

Both are applied through the same *layered* representation: each layer is a
set of disjoint adjacent pairs, so one layer is a pure element-wise
recombination ``y = c ⊙ x + s ⊙ x[partner]`` — the TPU-native (VPU) analogue
of a column of interfering MZIs.

Conventions
-----------
A rotation in plane ``(a, b)``, ``a < b``, with angle ``φ`` acts as::

    y_a = cos(φ) x_a − sin(φ) x_b
    y_b = sin(φ) x_a + cos(φ) x_b

(the paper's Eq. (7) planar rotator).  ``apply_mesh`` computes ``U @ x``
where ``x``'s LAST axis is the mixed dimension, with ``D`` applied first.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "MeshSpec",
    "mesh_spec",
    "num_phases",
    "apply_mesh",
    "apply_mesh_transpose",
    "build_unitary",
    "decompose_reck",
    "decompose_clements",
    "decompose",
    "random_orthogonal",
    "np_build_unitary",
]


def num_phases(k: int) -> int:
    return k * (k - 1) // 2


# ---------------------------------------------------------------------------
# Mesh schedules (static numpy metadata)
# ---------------------------------------------------------------------------


class MeshSpec(NamedTuple):
    """Static description of a k×k rotation mesh.

    All index arrays are plain numpy (hashable via id-based caching in
    ``mesh_spec``); they are closed over as constants when jitted.
    """

    k: int
    kind: str
    n_rot: int
    n_layers: int
    # application-ordered rotation list
    pairs: np.ndarray        # (T, 2) int32, pairs[t] = (a, b), a < b
    # layered representation
    layer_slot: np.ndarray   # (L, k) int32 — phase index feeding wire w, -1 idle
    layer_partner: np.ndarray  # (L, k) int32 — partner wire (self if idle)
    layer_sign: np.ndarray   # (L, k) float32 — -1 upper wire, +1 lower, 0 idle
    # crosstalk adjacency: neighbours of each phase within its layer
    phase_neighbors: np.ndarray  # (T, 2) int32, -1 padded


def _reck_null_order(k: int) -> list[tuple[int, int]]:
    """Column-major bottom-up Givens nulling order (triangular mesh)."""
    order = []
    for c in range(k - 1):
        for r in range(k - 1, c, -1):
            order.append((r - 1, r))
    return order


def _clements_apply_order(k: int) -> tuple[list[tuple[int, int]], list[int]]:
    """Rectangular mesh: k alternating even/odd layers of adjacent pairs.

    Returns (pairs in application order, layer id per rotation).
    """
    pairs, layer_of = [], []
    for layer in range(k):
        start = layer % 2
        for a in range(start, k - 1, 2):
            pairs.append((a, a + 1))
            layer_of.append(layer)
    return pairs, layer_of


def _layerize(pairs: list[tuple[int, int]], k: int,
              layer_of: list[int] | None = None):
    """Greedy layering of an application-ordered rotation list.

    Rotations on disjoint wires commute, so consecutive disjoint rotations can
    share a layer; a rotation must come strictly after any earlier rotation
    touching one of its wires.
    """
    T = len(pairs)
    if layer_of is None:
        avail = np.zeros(k, dtype=np.int64)
        layer_of = []
        for (a, b) in pairs:
            l = int(max(avail[a], avail[b]))
            layer_of.append(l)
            avail[a] = avail[b] = l + 1
    n_layers = (max(layer_of) + 1) if T else 0

    layer_slot = np.full((max(n_layers, 1), k), -1, dtype=np.int32)
    layer_partner = np.tile(np.arange(k, dtype=np.int32), (max(n_layers, 1), 1))
    layer_sign = np.zeros((max(n_layers, 1), k), dtype=np.float32)
    # per-layer ordered list of phase slots for crosstalk adjacency
    per_layer_slots: list[list[tuple[int, int]]] = [[] for _ in range(max(n_layers, 1))]
    for t, (a, b) in enumerate(pairs):
        l = layer_of[t]
        layer_slot[l, a] = t
        layer_slot[l, b] = t
        layer_partner[l, a] = b
        layer_partner[l, b] = a
        layer_sign[l, a] = -1.0
        layer_sign[l, b] = 1.0
        per_layer_slots[l].append((a, t))

    neigh = np.full((max(T, 1), 2), -1, dtype=np.int32)
    for slots in per_layer_slots:
        slots.sort()  # by wire position within the layer
        for i, (_, t) in enumerate(slots):
            if i > 0:
                neigh[t, 0] = slots[i - 1][1]
            if i + 1 < len(slots):
                neigh[t, 1] = slots[i + 1][1]
    return n_layers, layer_slot, layer_partner, layer_sign, neigh


@functools.lru_cache(maxsize=None)
def mesh_spec(k: int, kind: str = "reck") -> MeshSpec:
    if k < 2:
        raise ValueError(f"mesh size must be >= 2, got {k}")
    if kind == "reck":
        null_order = _reck_null_order(k)
        pairs = list(reversed(null_order))  # application order
        layer_of = None
    elif kind == "clements":
        pairs, layer_of = _clements_apply_order(k)
    else:
        raise ValueError(f"unknown mesh kind: {kind!r}")
    n_layers, slot, partner, sign, neigh = _layerize(pairs, k, layer_of)
    return MeshSpec(
        k=k,
        kind=kind,
        n_rot=len(pairs),
        n_layers=n_layers,
        pairs=np.asarray(pairs, dtype=np.int32).reshape(-1, 2),
        layer_slot=slot,
        layer_partner=partner,
        layer_sign=sign,
        phase_neighbors=neigh,
    )


# ---------------------------------------------------------------------------
# JAX application
# ---------------------------------------------------------------------------


def apply_mesh(spec: MeshSpec, phases: jax.Array, x: jax.Array,
               d: jax.Array | None = None) -> jax.Array:
    """Compute ``U(phases, d) @ x`` mixing ``x``'s last axis.

    phases: (..., T)  — batch dims broadcast against x's
    x:      (..., k)
    d:      (..., k) ±1 sign diagonal or None (identity)
    """
    if d is not None:
        x = x * d
    slot = jnp.asarray(spec.layer_slot)
    partner = jnp.asarray(spec.layer_partner)
    sign = jnp.asarray(spec.layer_sign, dtype=x.dtype)

    def one_layer(x, consts):
        sl, pt, sg = consts
        ph = jnp.take(phases, jnp.maximum(sl, 0), axis=-1)
        live = (sl >= 0)
        c = jnp.where(live, jnp.cos(ph), 1.0).astype(x.dtype)
        s = jnp.where(live, jnp.sin(ph), 0.0).astype(x.dtype) * sg
        return c * x + s * jnp.take(x, pt, axis=-1), None

    x, _ = jax.lax.scan(one_layer, x, (slot, partner, sign))
    return x


def apply_mesh_transpose(spec: MeshSpec, phases: jax.Array, x: jax.Array,
                         d: jax.Array | None = None) -> jax.Array:
    """Compute ``U(phases, d)^T @ x`` (= U^{-1} x, U orthogonal).

    U^T = D · R_0^T ··· R_{T-1}^T — layers in reverse with negated angles.
    """
    slot = jnp.asarray(spec.layer_slot[::-1].copy())
    partner = jnp.asarray(spec.layer_partner[::-1].copy())
    sign = jnp.asarray(spec.layer_sign[::-1].copy(), dtype=x.dtype)

    def one_layer(x, consts):
        sl, pt, sg = consts
        ph = jnp.take(phases, jnp.maximum(sl, 0), axis=-1)
        live = (sl >= 0)
        c = jnp.where(live, jnp.cos(ph), 1.0).astype(x.dtype)
        # transpose of the rotation: negate the angle -> flip the sign pattern
        s = jnp.where(live, jnp.sin(ph), 0.0).astype(x.dtype) * (-sg)
        return c * x + s * jnp.take(x, pt, axis=-1), None

    x, _ = jax.lax.scan(one_layer, x, (slot, partner, sign))
    if d is not None:
        x = x * d
    return x


def build_unitary(spec: MeshSpec, phases: jax.Array,
                  d: jax.Array | None = None) -> jax.Array:
    """Materialize ``U`` (..., k, k) from phases (..., T) and signs (..., k).

    Column j of U is ``U @ e_j``; we apply the mesh to the identity, treating
    the *column* index as a batch dim: rows get mixed, so we apply to eye
    transposed and transpose back.
    """
    k = spec.k
    eye = jnp.eye(k, dtype=phases.dtype)
    # batch: (..., k_cols, k) — mesh mixes last axis (rows of U)
    bshape = phases.shape[:-1]
    ph = jnp.broadcast_to(phases[..., None, :], bshape + (k, spec.n_rot or 1))
    dd = None
    if d is not None:
        dd = jnp.broadcast_to(d[..., None, :], bshape + (k, k))
    cols = apply_mesh(spec, ph, jnp.broadcast_to(eye, bshape + (k, k)), dd)
    # cols[..., j, :] = U @ e_j  -> U[..., :, j]
    return jnp.swapaxes(cols, -1, -2)


# ---------------------------------------------------------------------------
# Exact decomposition (numpy, float64)
# ---------------------------------------------------------------------------


def decompose_reck(Q: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Exact Reck-mesh decomposition of a real orthogonal ``Q``.

    Returns ``(phases, d)`` in *application order* such that
    ``U = R_{T-1} ··· R_0 · D == Q`` (matching :func:`apply_mesh`).

    Givens-null the subdiagonal column-major bottom-up; each left-applied
    nulling rotation ``G(θ)`` contributes ``R(θ) = G(θ)^T`` on the other side.
    """
    Q = np.asarray(Q, dtype=np.float64)
    k = Q.shape[0]
    if Q.shape != (k, k):
        raise ValueError(f"square matrix required, got {Q.shape}")
    A = Q.copy()
    thetas = []  # in nulling order
    for c in range(k - 1):
        for r in range(k - 1, c, -1):
            a, b = A[r - 1, c], A[r, c]
            th = np.arctan2(b, a)
            cth, sth = np.cos(th), np.sin(th)
            ra = cth * A[r - 1] + sth * A[r]
            rb = -sth * A[r - 1] + cth * A[r]
            A[r - 1], A[r] = ra, rb
            thetas.append(th)
    d = np.sign(np.diag(A))
    d[d == 0] = 1.0
    # application order = reversed nulling order
    phases = np.asarray(thetas[::-1], dtype=np.float64)
    return phases, d.astype(np.float64)


def decompose_clements(Q: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Exact Clements-mesh decomposition of a real orthogonal ``Q``.

    Real-valued variant of Clements et al. (Optica 2016): anti-diagonals of
    the lower triangle are nulled alternately with rotations multiplied from
    the right (columns; odd anti-diagonals) and from the left (rows; even
    anti-diagonals):  ``L_s···L_1 · Q · R_1···R_t = D0``  giving

        Q = L_1^T···L_s^T · D0 · R_t^T···R_1^T
          = L_1^T···L_s^T · R_t^T'···R_1^T' · D0

    using the commutation rule ``D R(θ) = R(d_a d_b θ) D`` for a ±1 diagonal.
    The resulting rotation sequence tiles exactly the rectangular Clements
    mesh of :func:`mesh_spec`; phases are returned in its slot order.

    Returns ``(phases, d)`` such that ``apply_mesh(spec, phases, x, d)``
    reproduces ``Q @ x`` with ``spec = mesh_spec(k, "clements")``.
    """
    Q = np.asarray(Q, dtype=np.float64)
    k = Q.shape[0]
    if Q.shape != (k, k):
        raise ValueError(f"square matrix required, got {Q.shape}")
    A = Q.copy()
    rights: list[tuple[int, float]] = []  # (upper wire a, θ) in applied order
    lefts: list[tuple[int, float]] = []

    for i in range(1, k):
        if i % 2 == 1:
            # null A[k-1-j, i-1-j] from the RIGHT via columns (c, c+1)
            for j in range(i):
                r, c = k - 1 - j, i - 1 - j
                x, y = A[r, c], A[r, c + 1]
                th = np.arctan2(-x, y)
                cth, sth = np.cos(th), np.sin(th)
                ca = cth * A[:, c] + sth * A[:, c + 1]
                cb = -sth * A[:, c] + cth * A[:, c + 1]
                A[:, c], A[:, c + 1] = ca, cb
                rights.append((c, th))
        else:
            # null A[k-i+j-1, j-1] from the LEFT via rows (r-1, r)
            for j in range(1, i + 1):
                r, c = k - i + j - 1, j - 1
                x, y = A[r - 1, c], A[r, c]
                th = np.arctan2(y, x)
                cth, sth = np.cos(th), np.sin(th)
                ra = cth * A[r - 1] + sth * A[r]
                rb = -sth * A[r - 1] + cth * A[r]
                A[r - 1], A[r] = ra, rb
                lefts.append((r - 1, th))

    d = np.sign(np.diag(A))
    d[d == 0] = 1.0

    # Assemble application-ordered rotation list for U = (rots)·D0.
    # R_m applied on the right contributes R^T(θ_m) = R(-θ_m); commuting D0
    # rightwards multiplies the angle by d_a·d_b.  L_m contributes R(-θ_m)
    # already left of D0.
    app: list[tuple[int, float]] = []
    for a, th in rights:  # R_1^T' applied first, ... R_t^T'
        app.append((a, -th * d[a] * d[a + 1]))
    # L_m as implemented is R(-θ_m), so L_m^T = R(+θ_m)
    for a, th in reversed(lefts):  # then L_s^T ... L_1^T
        app.append((a, th))

    # Map the application-ordered rotations onto the canonical Clements slots.
    spec = mesh_spec(k, "clements")
    slot_of: dict[tuple[int, int], int] = {}
    t = 0
    pairs, layer_of = _clements_apply_order(k)
    for (a, _b), l in zip(pairs, layer_of):
        slot_of[(l, a)] = t
        t += 1
    phases = np.zeros(spec.n_rot, dtype=np.float64)
    filled = np.zeros(spec.n_rot, dtype=bool)
    wire_free = np.zeros(k, dtype=np.int64)  # earliest layer each wire is free
    for a, th in app:
        l = int(max(wire_free[a], wire_free[a + 1]))
        # advance to the canonical layer with matching parity
        while (l % 2) != (a % 2) or (l, a) not in slot_of or filled[slot_of[(l, a)]]:
            l += 1
            if l > 2 * k:
                raise AssertionError("clements layer assignment failed")
        s = slot_of[(l, a)]
        phases[s] = th
        filled[s] = True
        wire_free[a] = wire_free[a + 1] = l + 1
    if not filled.all():
        raise AssertionError("clements decomposition did not fill every slot")
    return phases, d.astype(np.float64)


def decompose(Q: np.ndarray, kind: str = "reck"):
    if kind == "reck":
        return decompose_reck(Q)
    if kind == "clements":
        return decompose_clements(Q)
    raise ValueError(f"unknown mesh kind: {kind!r}")


# ---------------------------------------------------------------------------
# Reference helpers
# ---------------------------------------------------------------------------


def np_build_unitary(spec: MeshSpec, phases: np.ndarray,
                     d: np.ndarray | None = None) -> np.ndarray:
    """Pure-numpy float64 oracle for :func:`build_unitary`."""
    k = spec.k
    U = np.eye(k) if d is None else np.diag(np.asarray(d, dtype=np.float64))
    for t in range(spec.n_rot):
        a, b = spec.pairs[t]
        R = np.eye(k)
        c, s = np.cos(phases[t]), np.sin(phases[t])
        R[a, a] = c
        R[a, b] = -s
        R[b, a] = s
        R[b, b] = c
        U = R @ U
    return U


def random_orthogonal(key_or_seed, k: int) -> np.ndarray:
    """Haar-ish random real orthogonal matrix (numpy, float64)."""
    rng = np.random.default_rng(
        key_or_seed if isinstance(key_or_seed, (int, np.integer)) else None)
    M = rng.standard_normal((k, k))
    Qm, Rm = np.linalg.qr(M)
    return Qm * np.sign(np.diag(Rm))
