"""Subspace learning: first-order training of Σ with in-situ gradients.

The paper's SL stage (§3.4) trains ONLY the singular values.  The weight
gradient is obtained *in situ* via reciprocity (Eq. 5):

    ∂L/∂Σ_pq = (U_pq^T ∂L/∂y_p) ⊙ (V*_pq x_q)      summed over tokens,
    ∂L/∂x_q  = Σ_p 𝑃_W[q,p] · V_pq (Σ_pq ⊙ (U_pq^T ∂L/∂y_p))

i.e. one extra backward PTC pass for the upstream gradient, the forward
pass's V*x, and a Hadamard product (offloaded to electronics).  The sign
ambiguity Ĩ from Identity Calibration cancels in the product, so we never
model it here.

This module realizes that structure as a ``jax.custom_vjp`` so the same
sampled/unsampled estimator the chip would compute is what the optimizer
sees.  Two modes:

* ``blocked`` — paper-faithful dataflow: both fwd and bwd are batched
  k×k-block ops (what the photonic mesh physically does);
* ``fused``   — beyond-paper TPU path: forward recomposes ``W_eff`` for a
  single MXU matmul, backward computes the dense ``δyᵀx`` once and
  projects its block-diagonals (mathematically identical, ~2× fewer
  backward FLOPs; see DESIGN §6 / EXPERIMENTS §Perf).

Feedback / column masks are sampled OUTSIDE (``repro.core.sparsity``) and
passed in; ``None`` means dense.  Gradients for ``u``/``v`` are zero —
the bases are frozen hardware state (that is the whole point of subspace
learning).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .ptc import PTCParams, compose_weight, unblockize, blockize, block_energy
from .sparsity import SparsityConfig, feedback_mask, column_mask

__all__ = ["ptc_linear", "ptc_linear_ref", "SubspaceMasks", "sample_masks"]


class SubspaceMasks(NamedTuple):
    """Per-layer sampling masks for one optimization step."""

    feedback: jax.Array | None  # (Q, P) scaled block mask on W^T, or None
    column: jax.Array | None    # (T,) scaled token/column mask, or None


def sample_masks(key: jax.Array, params: PTCParams, n_tokens: int,
                 cfg: SparsityConfig) -> SubspaceMasks:
    """Draw the step's feedback + column masks for one PTC weight."""
    kf, kc = jax.random.split(key)
    fb = feedback_mask(kf, block_energy(params), cfg) if cfg.alpha_w < 1.0 else None
    col = column_mask(kc, n_tokens, cfg) if cfg.alpha_c < 1.0 else None
    return SubspaceMasks(feedback=fb, column=col)


# ---------------------------------------------------------------------------
# custom_vjp
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ptc_linear(mode: str, x, s, u, v, fb_mask, col_mask):
    """y = x @ W(U,Σ,V*)^T with the in-situ backward.  x: (..., Q·k)."""
    return _primal(mode, x, s, u, v)


def _primal(mode, x, s, u, v):
    p, q, k, _ = u.shape
    if mode == "fused":
        w = unblockize(compose_weight(PTCParams(u=u, s=s, v=v)))
        return x @ w.T
    xb = x.reshape(x.shape[:-1] + (q, k))
    yv = jnp.einsum("pqkj,...qj->...pqk", v, xb)
    y = jnp.einsum("pqik,...pqk->...pqi", u, yv * s)
    return y.sum(-2).reshape(x.shape[:-1] + (p * k,))


def _fwd(mode, x, s, u, v, fb_mask, col_mask):
    return _primal(mode, x, s, u, v), (x, s, u, v, fb_mask, col_mask)


def _flatten_tokens(a):
    """(..., D) → (T, D): the token axis the column mask indexes."""
    return a.reshape(-1, a.shape[-1])


def _bwd(mode, res, dy):
    x, s, u, v, fb_mask, col_mask = res
    p, q, k, _ = u.shape
    out_shape = x.shape
    xt = _flatten_tokens(x)                      # (T, Q·k)
    dyt = _flatten_tokens(dy)                    # (T, P·k)
    t = xt.shape[0]

    if mode == "fused":
        # --- beyond-paper dense backward (identical estimator) ---
        # dW = δyᵀ·(col ⊙ x); ds_pq = diag(U_pqᵀ dW_pq V_pqᵀ)
        xw = xt if col_mask is None else xt * col_mask[:, None]
        dw = dyt.T @ xw                          # (P·k, Q·k)
        dwb = blockize(dw, k)                    # (P, Q, k, k)
        udw = jnp.einsum("pqji,pqjl->pqil", u, dwb)
        ds = jnp.einsum("pqil,pqil->pqi", udw, v).astype(s.dtype)
        # dx = δy @ (fb ⊙_blocks W)
        w = compose_weight(PTCParams(u=u, s=s, v=v))  # (P, Q, k, k)
        if fb_mask is not None:
            w = w * fb_mask.T[:, :, None, None]
        dx = (dyt @ unblockize(w)).reshape(out_shape).astype(x.dtype)
    else:
        # --- paper-faithful in-situ dataflow ---
        xb = xt.reshape(t, q, k)
        dyb = dyt.reshape(t, p, k)
        gu = jnp.einsum("pqik,tpi->tpqk", u, dyb)        # U^T δy  (bwd PTC pass)
        xv = jnp.einsum("pqkj,tqj->tpqk", v, xb)         # V* x    (fwd PTC pass)
        guw = gu if col_mask is None else gu * col_mask[:, None, None, None]
        ds = jnp.einsum("tpqk,tpqk->pqk", guw, xv).astype(s.dtype)  # Hadamard ⊕ acc
        gus = gu * s                                      # Σ ⊙ ·
        if fb_mask is not None:
            gus = gus * fb_mask.T[None, :, :, None]       # 𝑃_W block mask
        dxb = jnp.einsum("pqkj,tpqk->tqj", v, gus)        # V · (error feedback)
        dx = dxb.reshape(out_shape).astype(x.dtype)

    none_fb = None if fb_mask is None else jnp.zeros_like(fb_mask)
    none_col = None if col_mask is None else jnp.zeros_like(col_mask)
    return (dx, ds, jnp.zeros_like(u), jnp.zeros_like(v), none_fb, none_col)


_ptc_linear.defvjp(_fwd, _bwd)


def ptc_linear(x: jax.Array, params: PTCParams,
               masks: SubspaceMasks | None = None, *,
               mode: str = "fused") -> jax.Array:
    """Public PTC linear: y = x @ W(params)^T with in-situ subspace VJP.

    ``x``'s last dim must equal Q·k (pad in the layer wrapper); the output
    is (..., P·k).  ``mode``: "fused" (TPU-optimized) or "blocked"
    (paper-faithful photonic dataflow).
    """
    if mode not in ("fused", "blocked"):
        raise ValueError(f"unknown mode: {mode!r}")
    fb = masks.feedback if masks is not None else None
    col = masks.column if masks is not None else None
    return _ptc_linear(mode, x, params.s, params.u, params.v, fb, col)


def ptc_linear_ref(x: jax.Array, params: PTCParams) -> jax.Array:
    """Pure-autodiff oracle (no custom_vjp, no sampling) for tests."""
    w = unblockize(compose_weight(params))
    return x @ w.T
