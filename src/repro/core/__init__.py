"""L²ight core: the paper's three-stage on-chip learning flow in JAX.

* ``unitary``     — MZI-mesh parametrization of orthogonal bases
* ``noise``       — Q/Γ/Ω/Φ_b circuit non-idealities
* ``ptc``         — blockwise-SVD photonic-tensor-core substrate
* ``calibration`` — stage 1: Identity Calibration (ZO)
* ``mapping``     — stage 2: Parallel Mapping + OSP
* ``subspace``    — stage 3: Σ-only training with in-situ gradients
* ``sparsity``    — multi-level sampling (feedback/column/data)
* ``profiler``    — Appendix-G PTC energy / time-step cost model
"""

from .unitary import mesh_spec, build_unitary, apply_mesh, decompose  # noqa: F401
from .noise import NoiseModel, IDEAL, DEFAULT_NOISE  # noqa: F401
from .ptc import (  # noqa: F401
    PTCParams, PTCPhaseParams, blockize, unblockize, svd_factorize,
    random_factorize, identity_factorize, compose_weight, block_energy,
    ptc_forward, ptc_forward_blocked, ptc_forward_fused,
)
from .sparsity import SparsityConfig, DENSE, feedback_mask, column_mask  # noqa: F401
from .subspace import ptc_linear, ptc_linear_ref, SubspaceMasks, sample_masks  # noqa: F401
from .calibration import calibrate_identity, ICResult  # noqa: F401
from .mapping import parallel_map, osp, matrix_distance, PMResult  # noqa: F401
from .profiler import LayerSpec, layer_cost, model_cost  # noqa: F401
