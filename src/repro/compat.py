"""jax version-compatibility shims.

The container pins jax 0.4.x, where ``shard_map`` still lives in
``jax.experimental.shard_map`` with the older keyword surface
(``check_rep`` instead of ``check_vma``; ``auto`` = the *non*-manual
axes instead of ``axis_names`` = the manual ones).  Newer jax exposes
``jax.shard_map`` directly.  Callers use the modern spelling and this
module translates when needed.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map"]

if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False,
                  axis_names=None):
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, **kw)

else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False,
                  axis_names=None):
        # ``axis_names`` (partial-manual) is ignored here: 0.4.x's
        # ``auto=`` spelling of it crashes XLA's SPMD partitioner on the
        # GPipe pattern (CHECK IsManualSubgroup).  Full-manual is
        # numerically identical — unnamed axes see replicated data
        # instead of partitioner-driven sharding — so correctness tests
        # hold; the partial-manual perf shape needs the newer toolchain.
        del axis_names
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)
